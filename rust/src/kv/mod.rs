//! KV Cache Adaptor (paper §4.2): a single physical block pool whose blocks
//! never move or resize, plus a logical table that re-interprets block
//! *token capacity* per parallelism mode:
//!
//!   M_block = B * D_local * P_size  is held constant          (Eq. 2)
//!   B(p)    = p * B_base                                      (Eq. 3)
//!
//! Mode transitions are therefore constant-time metadata updates; KV bytes
//! are never migrated.  Requests carry a *layout tag* (the TP degree their
//! KV was written under), which is what lets DP-layout and TP-layout blocks
//! coexist in one pool — the enabler for Hard Preempt (§5.2.3).
//!
//! The adaptor manages metadata only; the actual pool contents live in
//! device-resident PJRT buffers owned by the engines.  `slot()` is the
//! "stride and capacity" information the worker hands the attention kernel
//! (§4.2.3) — here surfaced as flat slot ids and padded block-table rows.
//!
//! # Hot-path discipline
//!
//! Per-request state lives in a generational dense slab
//! ([`crate::util::slab::Slab`]); [`register`](KvCacheAdaptor::register)
//! returns a [`KvHandle`] that the coordinator resolves **once at bind
//! time** and then uses for every per-step access — `slot_h`,
//! `table_row_ref_h`, `ensure_capacity_h`, `set_seq_len_h` are O(1) array
//! indexes, no id-map walk.  The id-keyed methods remain as thin wrappers
//! over a `BTreeMap<u64, KvHandle>` side index for cold paths (registration,
//! release, tests, external tooling); `check_invariants` asserts the side
//! index and the slab agree at all times.
//!
//! # Cross-request prefix sharing (ISSUE 10, `--prefix-cache`)
//!
//! [`enable_prefix_cache`](KvCacheAdaptor::enable_prefix_cache) arms an
//! optional refcounted radix/prefix tree over the same block pool (SGLang's
//! RadixAttention, made layout-aware): each tree node caches exactly one
//! DP-layout block's worth of prompt tokens.  Admission probes the tree
//! ([`prefix_probe`](KvCacheAdaptor::prefix_probe)), adopts the matched
//! chain by reference ([`prefix_adopt`](KvCacheAdaptor::prefix_adopt) —
//! refcount bump, no prefill), and finished requests donate their novel
//! prompt blocks ([`prefix_donate`](KvCacheAdaptor::prefix_donate) — the
//! copy-on-write fork point: divergent suffixes insert new nodes, shared
//! content is never duplicated).  With the cache armed, block ownership
//! becomes refcounted (request lists + tree each count one owner); a block
//! returns to the free list only at refcount 0.  Refcount-1 tree leaves
//! (cache-only owners) are LRU-evicted on demand — the cache *borrows*
//! pool capacity, allocation pressure always wins.  Migration composes:
//! re-tagged blocks are epoch-marked so co-migrating sharers scatter the
//! shared prefix exactly once per switch, and consumed tree entries (now
//! non-DP layout) are invalidated.  With the cache off (`prefix: None`)
//! every path below is byte-identical to the pre-ISSUE-10 code.

use anyhow::{bail, Result};

use crate::model::ModelCfg;
use crate::util::slab::{Slab, SlabHandle};

/// Generation-checked O(1) handle to one request's KV state, returned by
/// [`KvCacheAdaptor::register`].  Each adaptor instance hands out its own
/// handles (TP members register the same rid independently, so the same
/// request has one handle *per member adaptor*).
pub type KvHandle = SlabHandle;

/// Reserved physical block: padded batch slots write their (masked) tokens
/// here so kernels need no conditionals.  Never allocated to a request.
pub const TRASH_BLOCK: u32 = 0;

/// One request's cross-layout migration recipe (ISSUE 4): how its cached KV
/// is carried from `from_p` to `to_p` **without recompute**, exploiting the
/// block invariants of Eqs. 2–3 — the same physical bytes cover the home
/// rank's `1/p` head slice for `p×` tokens, so the home side re-tags a
/// prefix of its existing blocks in place (zero copy) and only the other
/// members' slices cross the interconnect (scatter; the TP→DP direction is
/// the inverse gather).
///
/// Produced by [`KvCacheAdaptor::plan_migration`] against current adaptor
/// state and executed by [`KvCacheAdaptor::apply_migration`].  Every field
/// is a reusable buffer/scalar: callers keep one plan in their step scratch
/// and the plan/apply pair performs zero steady-state heap allocation once
/// warm (the PR-1 coordinator invariant).
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    pub rid: u64,
    pub from_p: usize,
    pub to_p: usize,
    /// Tokens whose KV the plan carries across the layout change.
    pub seq_len: usize,
    /// Home-side blocks re-tagged in place as `to_p`-layout views: always a
    /// prefix of the request's block list, zero bytes moved.
    pub retag: Vec<u32>,
    /// Home-side surplus blocks returned to the pool (promote direction:
    /// `to_p > from_p` shrinks the per-member block count).
    pub free: Vec<u32>,
    /// Blocks the home side must newly allocate (demote/gather direction:
    /// `to_p < from_p` grows the per-member block count).
    pub grow: usize,
    /// Blocks each of the other group members must allocate fresh to hold
    /// their scattered slice (equals `retag.len() + grow`).
    pub peer_blocks: usize,
    /// f32 elements of one member's slice (`seq_len * kv_width(wide)`), the
    /// unit the scatter/gather data plane moves per member.
    pub elems_per_member: usize,
    /// Bytes that must cross the interconnect: the `(wide-1)/wide` fraction
    /// of the request's KV footprint not already resident at its
    /// destination (`wide = max(from_p, to_p)`).  This is the numerator of
    /// the cost model's `migrate_t`.
    pub link_bytes: usize,
}

/// Sentinel for "no tree node" in [`PrefixPool`] index vectors.
const NO_NODE: u32 = u32::MAX;

/// One node of the prefix tree: exactly one DP-layout block's worth of
/// prompt tokens plus the physical block caching their KV.  Divergent
/// continuations hang off `children` — the copy-on-write fork point.
#[derive(Clone, Debug)]
struct PrefixNode {
    /// Parent node index, `NO_NODE` for a top-level (root-child) node.
    parent: u32,
    /// Exactly `block_tokens(1)` prompt tokens (partial blocks never enter
    /// the tree, so every match is block-aligned by construction).
    tokens: Vec<i32>,
    /// Physical block whose KV caches `tokens` (DP layout, p = 1).
    block: u32,
    children: Vec<u32>,
    /// LRU stamp, bumped on every probe/adopt/donate walk that touches the
    /// node; refcount-1 leaves with the oldest stamp are evicted first.
    last_use: u64,
    live: bool,
}

/// Refcounted radix/prefix tree over KV blocks (ISSUE 10).  Owned by
/// [`KvCacheAdaptor`] behind an `Option` — `None` means the prefix cache is
/// off and block ownership stays exclusive (the PR-1..9 discipline,
/// byte-identical).  All block-id vectors are indexed by physical block id.
pub struct PrefixPool {
    nodes: Vec<PrefixNode>,
    /// Dead `nodes` slots available for reuse.
    node_free: Vec<u32>,
    /// Top-level nodes (first block of each cached prompt family).
    roots: Vec<u32>,
    /// Per-block owner count: every request whose block list contains the
    /// block counts 1, and a tree node holding the block counts 1.  A block
    /// is on the adaptor's free list iff its refcount is 0.
    refcounts: Vec<u32>,
    /// block id -> owning tree node (`NO_NODE` when not cached).
    node_of_block: Vec<u32>,
    /// Switch epoch in which each block was last re-tagged/scattered —
    /// lets a co-migrating sharer's plan skip bytes a peer already moved
    /// this epoch ("scattered exactly once per switch").
    migrated_epoch: Vec<u64>,
    current_epoch: u64,
    lru_clock: u64,
    /// Blocks LRU-evicted since the last [`KvCacheAdaptor::take_prefix_evicted`]
    /// drain (feeds the `prefix_evict` journal event).
    evicted_pending: u32,
}

impl PrefixPool {
    fn new(n_blocks: usize) -> Self {
        PrefixPool {
            nodes: Vec::new(),
            node_free: Vec::new(),
            roots: Vec::new(),
            refcounts: vec![0; n_blocks],
            node_of_block: vec![NO_NODE; n_blocks],
            migrated_epoch: vec![0; n_blocks],
            current_epoch: 0,
            lru_clock: 0,
            evicted_pending: 0,
        }
    }

    /// Child of `at` (or a root when `at` is `None`) whose tokens equal
    /// `seg`, if any.
    fn find_child(&self, at: Option<u32>, seg: &[i32]) -> Option<u32> {
        let kids = match at {
            None => &self.roots,
            Some(i) => &self.nodes[i as usize].children,
        };
        kids.iter()
            .copied()
            .find(|&c| self.nodes[c as usize].tokens[..] == *seg)
    }

    /// Drop one refcount on `b`; a block at refcount 0 returns to `free`.
    fn deref_block(&mut self, b: u32, free: &mut Vec<u32>) {
        let r = &mut self.refcounts[b as usize];
        debug_assert!(*r > 0, "double free of block {b}");
        *r = r.saturating_sub(1);
        if *r == 0 {
            free.push(b);
        }
    }

    /// Unlink `idx` from its parent's child list (or the root list).
    fn detach(&mut self, idx: u32) {
        let parent = self.nodes[idx as usize].parent;
        let list = match parent {
            NO_NODE => &mut self.roots,
            p => &mut self.nodes[p as usize].children,
        };
        if let Some(i) = list.iter().position(|&c| c == idx) {
            list.swap_remove(i);
        }
    }

    /// Mark `idx` dead and recycle its slot (caller already detached it and
    /// settled its block's refcount).
    fn kill_node(&mut self, idx: u32) {
        let node = &mut self.nodes[idx as usize];
        node.live = false;
        node.children.clear();
        node.tokens.clear();
        self.node_of_block[node.block as usize] = NO_NODE;
        self.node_free.push(idx);
    }

    fn new_node(&mut self, node: PrefixNode) -> u32 {
        match self.node_free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        }
    }

    /// Evict the least-recently-used refcount-1 leaf (a block only the
    /// cache still owns) back into `free`.  Returns false when nothing is
    /// evictable — every cached block is still shared with a live request.
    fn evict_lru_leaf(&mut self, free: &mut Vec<u32>) -> bool {
        let mut best: Option<(u64, u32)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            if n.live
                && n.children.is_empty()
                && self.refcounts[n.block as usize] == 1
                && best.map_or(true, |(lu, _)| n.last_use < lu)
            {
                best = Some((n.last_use, i as u32));
            }
        }
        let Some((_, idx)) = best else { return false };
        self.detach(idx);
        let b = self.nodes[idx as usize].block;
        self.kill_node(idx);
        self.deref_block(b, free);
        debug_assert_eq!(self.refcounts[b as usize], 0);
        self.evicted_pending += 1;
        true
    }

    /// Remove the subtree rooted at `idx`, dropping the tree's refcount on
    /// every node's block (migration consumed those cache entries — the
    /// bytes are no longer DP-layout).  Blocks still shared with live
    /// requests survive; cache-only blocks return to `free`.
    fn remove_subtree(&mut self, idx: u32, free: &mut Vec<u32>) {
        self.detach(idx);
        let mut stack = vec![idx];
        while let Some(i) = stack.pop() {
            stack.extend(std::mem::take(&mut self.nodes[i as usize].children));
            let b = self.nodes[i as usize].block;
            self.kill_node(i);
            self.deref_block(b, free);
        }
    }
}

#[derive(Clone, Debug)]
pub struct RequestKv {
    pub rid: u64,         // external request id (for invariants/iteration)
    pub layout_p: usize,  // TP degree the KV bytes were written under
    pub blocks: Vec<u32>, // physical block ids, logical order
    pub seq_len: usize,   // tokens currently cached
    pub paused: bool,     // hard-preempted (KV stays resident)
    /// Cached kernel-facing block-table row, padded to `n_blocks` with
    /// `TRASH_BLOCK`.  Maintained incrementally by `ensure_capacity` /
    /// `relayout_for_recompute` so the serving hot path never rebuilds it.
    row: Vec<i32>,
}

/// Pool + logical-table state for one engine (DP mode) or one TP group
/// (members share identical block ids; each stores its own head slice, so
/// one adaptor instance describes all of them).
pub struct KvCacheAdaptor {
    cfg: ModelCfg,
    free: Vec<u32>, // LIFO free list of physical block ids
    requests: Slab<RequestKv>,
    /// rid -> handle side index (cold paths only; hot paths carry handles).
    by_id: std::collections::BTreeMap<u64, KvHandle>,
    /// Prefix-sharing state (`--prefix-cache`); `None` keeps every path in
    /// this module byte-identical to the exclusive-ownership code.
    prefix: Option<Box<PrefixPool>>,
}

impl KvCacheAdaptor {
    pub fn new(cfg: ModelCfg) -> Self {
        // Block 0 reserved; free list LIFO over the rest.
        let free = (1..cfg.n_blocks as u32).rev().collect();
        KvCacheAdaptor {
            cfg,
            free,
            requests: Slab::new(),
            by_id: Default::default(),
            prefix: None,
        }
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        (self.cfg.n_blocks - 1) - self.free.len()
    }

    /// Handle for a registered rid (cold path; hot paths keep the handle
    /// returned by [`Self::register`]).
    pub fn handle_of(&self, rid: u64) -> Option<KvHandle> {
        self.by_id.get(&rid).copied()
    }

    pub fn request(&self, rid: u64) -> Option<&RequestKv> {
        self.by_id.get(&rid).and_then(|&h| self.requests.get(h))
    }

    pub fn request_h(&self, h: KvHandle) -> Option<&RequestKv> {
        self.requests.get(h)
    }

    pub fn active_requests(&self) -> impl Iterator<Item = (u64, &RequestKv)> {
        self.requests.iter().map(|(_, r)| (r.rid, r))
    }

    /// Register a request under layout `p` (no blocks yet).  The returned
    /// handle is the O(1) key for every subsequent hot-path access.
    pub fn register(&mut self, rid: u64, p: usize) -> Result<KvHandle> {
        if !self.cfg.supports_tp(p) {
            bail!("unsupported TP degree {p}");
        }
        if self.by_id.contains_key(&rid) {
            bail!("request {rid} already registered");
        }
        let h = self.requests.insert(RequestKv {
            rid,
            layout_p: p,
            blocks: Vec::new(),
            seq_len: 0,
            paused: false,
            row: vec![TRASH_BLOCK as i32; self.cfg.n_blocks],
        });
        self.by_id.insert(rid, h);
        Ok(h)
    }

    fn resolve(&self, rid: u64) -> Result<KvHandle> {
        self.by_id
            .get(&rid)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("request {rid} not registered"))
    }

    /// Grow the request's block list so it can hold `n_tokens` under its
    /// layout.  Fails (leaving state unchanged) if the pool can't supply the
    /// blocks — the scheduler's OOM signal for Use Case 3 routing.  O(new
    /// blocks) — zero work when capacity already suffices.
    pub fn ensure_capacity_h(&mut self, h: KvHandle, n_tokens: usize) -> Result<()> {
        let (need, have, rid, layout_p) = match self.requests.get(h) {
            Some(req) => {
                let bt = self.cfg.block_tokens(req.layout_p);
                (n_tokens.div_ceil(bt), req.blocks.len(), req.rid, req.layout_p)
            }
            None => bail!("stale kv handle (request gone)"),
        };
        if need > self.cfg.n_blocks - 1 {
            bail!(
                "request {rid} needs {need} blocks > pool capacity {} (max ctx at p={} is {})",
                self.cfg.n_blocks - 1,
                layout_p,
                self.cfg.tp_token_capacity(layout_p)
            );
        }
        if need > have {
            let short = need - have;
            if !self.reserve_free(short) {
                bail!(
                    "kv pool exhausted: request {rid} short {short} blocks, {} free",
                    self.free.len()
                );
            }
            let req = self.requests.get_mut(h).unwrap();
            for _ in 0..short {
                let b = self.free.pop().unwrap();
                if let Some(px) = self.prefix.as_mut() {
                    debug_assert_eq!(px.refcounts[b as usize], 0);
                    px.refcounts[b as usize] = 1;
                }
                // Incremental row maintenance: only the newly-granted
                // positions are touched.
                req.row[req.blocks.len()] = b as i32;
                req.blocks.push(b);
            }
        }
        Ok(())
    }

    /// Ensure at least `n` blocks sit on the free list, LRU-evicting
    /// cache-only prefix leaves if the tree is borrowing capacity.  With
    /// the prefix cache off this is exactly the old `n <= free.len()`
    /// check.  Returns false when demand cannot be met even after
    /// evicting everything evictable.
    fn reserve_free(&mut self, n: usize) -> bool {
        while self.free.len() < n {
            let Some(px) = self.prefix.as_mut() else {
                return false;
            };
            if !px.evict_lru_leaf(&mut self.free) {
                return false;
            }
        }
        true
    }

    /// Id-keyed convenience form of [`Self::ensure_capacity_h`].
    pub fn ensure_capacity(&mut self, rid: u64, n_tokens: usize) -> Result<()> {
        let h = self.resolve(rid)?;
        self.ensure_capacity_h(h, n_tokens)
    }

    /// Record that the request now caches `seq_len` tokens (post-append).
    pub fn set_seq_len_h(&mut self, h: KvHandle, seq_len: usize) -> Result<()> {
        let req = self
            .requests
            .get_mut(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        let bt = self.cfg.block_tokens(req.layout_p);
        if seq_len.div_ceil(bt) > req.blocks.len() {
            bail!("seq_len {seq_len} exceeds allocated capacity");
        }
        req.seq_len = seq_len;
        Ok(())
    }

    pub fn set_seq_len(&mut self, rid: u64, seq_len: usize) -> Result<()> {
        let h = self.resolve(rid)?;
        self.set_seq_len_h(h, seq_len)
    }

    /// Flat slot id for token position `pos` — the kernel-facing "stride and
    /// capacity" mapping (§4.2.3).  O(1): one slab index + one block index.
    #[inline]
    pub fn slot_h(&self, h: KvHandle, pos: usize) -> Result<u32> {
        let req = self
            .requests
            .get(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        let bt = self.cfg.block_tokens(req.layout_p);
        let blk = *req
            .blocks
            .get(pos / bt)
            .ok_or_else(|| anyhow::anyhow!("position {pos} beyond allocated blocks"))?;
        Ok(blk * bt as u32 + (pos % bt) as u32)
    }

    pub fn slot(&self, rid: u64, pos: usize) -> Result<u32> {
        let h = self.resolve(rid)?;
        self.slot_h(h, pos)
    }

    /// Borrowed view of the block-table row, padded to the static artifact
    /// width (n_blocks).  This is the hot-path accessor: the row is cached
    /// and maintained incrementally, so this is an O(1) pointer handoff —
    /// callers copy it straight into their step buffers without any rebuild.
    #[inline]
    pub fn table_row_ref_h(&self, h: KvHandle) -> Result<&[i32]> {
        self.requests
            .get(h)
            .map(|req| req.row.as_slice())
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))
    }

    pub fn table_row_ref(&self, rid: u64) -> Result<&[i32]> {
        let h = self.resolve(rid)?;
        self.table_row_ref_h(h)
    }

    /// Block-table row padded to the static artifact width (n_blocks).
    /// Allocating convenience form of [`Self::table_row_ref`].
    pub fn table_row(&self, rid: u64) -> Result<Vec<i32>> {
        Ok(self.table_row_ref(rid)?.to_vec())
    }

    /// Hard Preempt: pause a request in place.  Its blocks stay resident
    /// under their original layout tag; O(1), no data movement (§5.2.3).
    pub fn pause(&mut self, rid: u64) -> Result<()> {
        let h = self.resolve(rid)?;
        self.requests.get_mut(h).unwrap().paused = true;
        Ok(())
    }

    pub fn resume(&mut self, rid: u64) -> Result<()> {
        let h = self.resolve(rid)?;
        self.requests.get_mut(h).unwrap().paused = false;
        Ok(())
    }

    /// Soft Preempt bind: the request's speculative DP-layout KV is
    /// incompatible with the target TP layout; drop its blocks and re-tag so
    /// prefill re-runs under the new layout (§5.2.2).  Returns the number of
    /// tokens that must be recomputed.  The handle stays valid (same
    /// registration, new layout tag).
    pub fn relayout_for_recompute(&mut self, rid: u64, new_p: usize) -> Result<usize> {
        if !self.cfg.supports_tp(new_p) {
            bail!("unsupported TP degree {new_p}");
        }
        let h = self.resolve(rid)?;
        let req = self.requests.get_mut(h).unwrap();
        let recompute = req.seq_len;
        let blocks = std::mem::take(&mut req.blocks);
        req.seq_len = 0;
        req.layout_p = new_p;
        req.row.fill(TRASH_BLOCK as i32);
        match self.prefix.as_mut() {
            Some(px) => {
                for &b in blocks.iter().rev() {
                    px.deref_block(b, &mut self.free);
                }
            }
            None => self.free.extend(blocks.into_iter().rev()),
        }
        Ok(recompute)
    }

    /// Plan a layout-preserving migration of this request's cached KV to
    /// degree `new_p` (ISSUE 4): the recipe that lets a DP↔TP switch carry
    /// the KV instead of recomputing it.  Read-only — computes into the
    /// caller's reusable `plan` buffers and fails (leaving everything
    /// unchanged) if the pool cannot supply a demote-direction grow.
    pub fn plan_migration(
        &self,
        h: KvHandle,
        new_p: usize,
        plan: &mut MigrationPlan,
    ) -> Result<()> {
        if !self.cfg.supports_tp(new_p) {
            bail!("unsupported TP degree {new_p}");
        }
        let req = self
            .requests
            .get(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        let seq = req.seq_len;
        let bt_new = self.cfg.block_tokens(new_p);
        let need_new = seq.div_ceil(bt_new);
        let have = req.blocks.len();
        let keep = need_new.min(have);
        let grow = need_new - keep;
        if grow > self.free.len() {
            bail!(
                "kv pool exhausted: migration of request {} to p={new_p} needs {grow} more blocks, {} free",
                req.rid,
                self.free.len()
            );
        }
        plan.rid = req.rid;
        plan.from_p = req.layout_p;
        plan.to_p = new_p;
        plan.seq_len = seq;
        plan.retag.clear();
        plan.retag.extend_from_slice(&req.blocks[..keep]);
        plan.free.clear();
        plan.free.extend_from_slice(&req.blocks[keep..]);
        plan.grow = grow;
        plan.peer_blocks = need_new;
        let wide = req.layout_p.max(new_p);
        // Scatter-once per switch (ISSUE 10): leading blocks a co-migrating
        // sharer already re-tagged/scattered this epoch carry no new bytes —
        // discount them from the data-plane cost.  Metadata (retag/free/
        // grow) stays per-request; only the wire cost dedupes.
        let mut already_tokens = 0usize;
        if let Some(px) = &self.prefix {
            if px.current_epoch > 0 {
                let bt_from = self.cfg.block_tokens(req.layout_p);
                for &b in &req.blocks[..keep] {
                    if px.migrated_epoch[b as usize] == px.current_epoch {
                        already_tokens += bt_from;
                    } else {
                        break;
                    }
                }
            }
        }
        let move_tokens = seq.saturating_sub(already_tokens);
        plan.elems_per_member = move_tokens * self.cfg.kv_width(wide);
        plan.link_bytes = 4 * plan.elems_per_member * (wide - 1);
        Ok(())
    }

    /// Execute a [`MigrationPlan`] on this (home-side) adaptor: re-tag the
    /// kept prefix in place, return surplus blocks to the pool (promote) or
    /// allocate the shortfall (demote), and re-tag the request under the new
    /// layout.  The cached row is maintained incrementally (prefix ids are
    /// untouched); `seq_len` is preserved — nothing needs recomputing.  The
    /// handle stays valid.  Other group members hold no prior state for the
    /// request and simply `register` + `ensure_capacity` their fresh blocks,
    /// then receive their slices through `Communicator::scatter_into`.
    pub fn apply_migration(&mut self, h: KvHandle, plan: &MigrationPlan) -> Result<()> {
        if !self.cfg.supports_tp(plan.to_p) {
            bail!("unsupported TP degree {}", plan.to_p);
        }
        let req = self
            .requests
            .get(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        if req.rid != plan.rid || req.layout_p != plan.from_p || req.seq_len != plan.seq_len {
            bail!(
                "stale migration plan for request {} (state moved since planning)",
                req.rid
            );
        }
        let keep = plan.retag.len();
        if req.blocks.len() != keep + plan.free.len()
            || req.blocks[..keep] != plan.retag[..]
            || req.blocks[keep..] != plan.free[..]
        {
            bail!("migration plan does not match request {}'s block list", req.rid);
        }
        if !self.reserve_free(plan.grow) {
            bail!("kv pool exhausted mid-migration (plan is stale)");
        }
        let req = self.requests.get_mut(h).unwrap();
        // Promote: surplus blocks leave from the tail (the retagged prefix
        // keeps its ids, so the cached row prefix is already correct).
        // With sharing armed a freed block may still be owned by other
        // sharers or the tree — it only reaches the free list at refcount 0.
        for i in (keep..req.blocks.len()).rev() {
            let b = req.blocks[i];
            req.row[i] = TRASH_BLOCK as i32;
            match self.prefix.as_mut() {
                Some(px) => px.deref_block(b, &mut self.free),
                None => self.free.push(b),
            }
        }
        req.blocks.truncate(keep);
        // Demote: grow the shortfall from the pool (checked above).
        for _ in 0..plan.grow {
            let b = self.free.pop().unwrap();
            if let Some(px) = self.prefix.as_mut() {
                debug_assert_eq!(px.refcounts[b as usize], 0);
                px.refcounts[b as usize] = 1;
            }
            req.row[req.blocks.len()] = b as i32;
            req.blocks.push(b);
        }
        req.layout_p = plan.to_p;
        debug_assert!(req.seq_len <= req.blocks.len() * self.cfg.block_tokens(plan.to_p));
        if let Some(px) = self.prefix.as_mut() {
            // Epoch-mark the re-tagged prefix so a co-migrating sharer's
            // plan skips bytes this apply already scattered, and invalidate
            // tree entries whose blocks the migration consumed — their
            // contents are no longer the DP layout future adopters expect.
            // (The *sharers'* reuse survives: block ids are stable, so every
            // sharer's block list and cached row remain valid as-is.)
            for &b in &plan.retag {
                px.migrated_epoch[b as usize] = px.current_epoch;
            }
            for &b in plan.retag.iter().chain(plan.free.iter()) {
                let idx = px.node_of_block[b as usize];
                if idx != NO_NODE {
                    px.remove_subtree(idx, &mut self.free);
                }
            }
        }
        Ok(())
    }

    /// Finish/abort a request: return its blocks to the pool and invalidate
    /// every copy of its handle.
    pub fn release_h(&mut self, h: KvHandle) -> Result<()> {
        let req = self
            .requests
            .remove(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        self.by_id.remove(&req.rid);
        match self.prefix.as_mut() {
            Some(px) => {
                // Shared prefix blocks survive the sharer: only refcount-0
                // blocks (no other sharer, not cached in the tree) return
                // to the pool.
                for &b in req.blocks.iter().rev() {
                    px.deref_block(b, &mut self.free);
                }
            }
            None => self.free.extend(req.blocks.into_iter().rev()),
        }
        Ok(())
    }

    pub fn release(&mut self, rid: u64) -> Result<()> {
        let h = self.resolve(rid)?;
        self.release_h(h)
    }

    /// Stale-tolerant release (ISSUE 6): reclaim the registration if the
    /// handle is still live, report whether anything was released.  Fault
    /// recovery walks a request's captured handles after arbitrary
    /// interleavings of finish/migrate/recovery — a handle that already
    /// died (generation bumped) is a no-op here, never a panic and never
    /// an error.
    pub fn release_if_live_h(&mut self, h: KvHandle) -> bool {
        if self.requests.get(h).is_none() {
            return false;
        }
        self.release_h(h).is_ok()
    }

    /// The mode-switch primitive measured in Table 2: binding/releasing a
    /// TP group changes no adaptor state at all — existing requests keep
    /// their layout tags, new requests are registered under the new degree.
    /// This method exists to document (and let benches measure) that the
    /// switch cost is O(1) metadata.
    pub fn switch_mode_metadata_cost(&self) -> usize {
        0 // no per-block work: the pool and ids are layout-invariant
    }

    // -----------------------------------------------------------------
    // Cross-request prefix sharing (ISSUE 10, `--prefix-cache`)
    // -----------------------------------------------------------------

    /// Arm the prefix cache.  Idempotent; safe mid-run (refcounts are
    /// seeded from current exclusive ownership).  There is deliberately no
    /// disarm: refcounted state cannot collapse back to exclusive
    /// ownership while blocks are shared.
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_some() {
            return;
        }
        let mut px = Box::new(PrefixPool::new(self.cfg.n_blocks));
        for (_, req) in self.requests.iter() {
            for &b in &req.blocks {
                px.refcounts[b as usize] += 1;
            }
        }
        self.prefix = Some(px);
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Number of live tree nodes (== blocks the cache holds a ref on).
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix
            .as_ref()
            .map_or(0, |px| px.nodes.iter().filter(|n| n.live).count())
    }

    /// Longest cached prefix of `tokens`, in tokens (always a multiple of
    /// the DP block size; 0 when the cache is off or cold).  Bumps the LRU
    /// stamp on the matched chain.  The caller feeds this through
    /// `sched::prefix_hit` — the single match predicate — before adopting.
    pub fn prefix_probe(&mut self, tokens: &[i32]) -> usize {
        let bt = self.cfg.block_tokens(1);
        let Some(px) = self.prefix.as_mut() else {
            return 0;
        };
        px.lru_clock += 1;
        let clock = px.lru_clock;
        let mut matched = 0usize;
        let mut at: Option<u32> = None;
        while matched + bt <= tokens.len() {
            let seg = &tokens[matched..matched + bt];
            let Some(c) = px.find_child(at, seg) else { break };
            px.nodes[c as usize].last_use = clock;
            matched += bt;
            at = Some(c);
        }
        matched
    }

    /// Adopt `reuse_tokens` of cached prefix for a freshly-registered DP
    /// request: bump each chain block's refcount, splice the block ids into
    /// the request's list (row maintained incrementally), and mark those
    /// tokens as already cached (`seq_len = reuse_tokens`) — they are never
    /// prefilled.  `reuse_tokens` must be the (block-aligned) output of
    /// `sched::prefix_hit` over a fresh probe.
    pub fn prefix_adopt(
        &mut self,
        h: KvHandle,
        tokens: &[i32],
        reuse_tokens: usize,
    ) -> Result<()> {
        if reuse_tokens == 0 {
            return Ok(());
        }
        let bt = self.cfg.block_tokens(1);
        if self.prefix.is_none() {
            bail!("prefix cache disabled");
        }
        if reuse_tokens % bt != 0 || reuse_tokens > tokens.len() {
            bail!("prefix adoption of {reuse_tokens} tokens is not block-aligned");
        }
        {
            let req = self
                .requests
                .get(h)
                .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
            if req.layout_p != 1 || !req.blocks.is_empty() || req.seq_len != 0 {
                bail!(
                    "prefix adoption requires a fresh DP registration (request {})",
                    req.rid
                );
            }
        }
        let px = self.prefix.as_mut().unwrap();
        px.lru_clock += 1;
        let clock = px.lru_clock;
        let mut chain: Vec<u32> = Vec::with_capacity(reuse_tokens / bt);
        let mut at: Option<u32> = None;
        let mut off = 0usize;
        while off < reuse_tokens {
            let seg = &tokens[off..off + bt];
            let Some(c) = px.find_child(at, seg) else {
                bail!("prefix chain shorter than the probed hit (cache raced)");
            };
            let b = px.nodes[c as usize].block;
            px.nodes[c as usize].last_use = clock;
            px.refcounts[b as usize] += 1;
            chain.push(b);
            at = Some(c);
            off += bt;
        }
        let req = self.requests.get_mut(h).unwrap();
        for (i, &b) in chain.iter().enumerate() {
            req.row[i] = b as i32;
            req.blocks.push(b);
        }
        req.seq_len = reuse_tokens;
        Ok(())
    }

    /// Donate a finished request's prompt blocks to the tree (the
    /// copy-on-write fork: shared content descends the existing chain,
    /// novel continuations insert new nodes that take a +1 ref on the
    /// donor's blocks, so they outlive the donor's release).  Only full
    /// DP-layout prompt blocks enter — the partial tail block (prompt tail
    /// + generated tokens) never does.  Returns the number of novel blocks
    /// cached (0 = everything was already cached, or the cache is off, or
    /// the request is not in DP layout).
    pub fn prefix_donate(&mut self, h: KvHandle, tokens: &[i32]) -> Result<usize> {
        if self.prefix.is_none() {
            return Ok(0);
        }
        let bt = self.cfg.block_tokens(1);
        let req = self
            .requests
            .get(h)
            .ok_or_else(|| anyhow::anyhow!("stale kv handle (request gone)"))?;
        if req.layout_p != 1 || req.paused {
            return Ok(0);
        }
        let n_full = (tokens.len() / bt)
            .min(req.blocks.len())
            .min(req.seq_len / bt);
        let donor: Vec<u32> = req.blocks[..n_full].to_vec();
        let px = self.prefix.as_mut().unwrap();
        px.lru_clock += 1;
        let clock = px.lru_clock;
        let mut at: Option<u32> = None;
        let mut inserted = 0usize;
        for (i, &b) in donor.iter().enumerate() {
            let seg = &tokens[i * bt..(i + 1) * bt];
            match px.find_child(at, seg) {
                Some(c) => {
                    // Shared content: keep the tree's copy, never duplicate.
                    px.nodes[c as usize].last_use = clock;
                    at = Some(c);
                }
                None => {
                    if px.node_of_block[b as usize] != NO_NODE {
                        // Defensive: the donor's block is already cached
                        // under different content — stop donating rather
                        // than double-insert (skip-never-panic).
                        break;
                    }
                    let idx = px.new_node(PrefixNode {
                        parent: at.unwrap_or(NO_NODE),
                        tokens: seg.to_vec(),
                        block: b,
                        children: Vec::new(),
                        last_use: clock,
                        live: true,
                    });
                    match at {
                        None => px.roots.push(idx),
                        Some(p) => px.nodes[p as usize].children.push(idx),
                    }
                    px.node_of_block[b as usize] = idx;
                    px.refcounts[b as usize] += 1;
                    inserted += 1;
                    at = Some(idx);
                }
            }
        }
        Ok(inserted)
    }

    /// Open a new switch epoch: the next migration through this adaptor
    /// scatters shared blocks at most once until the next call.  Called by
    /// the coordinator when a transition window opens.
    pub fn begin_switch_epoch(&mut self) {
        if let Some(px) = self.prefix.as_mut() {
            px.current_epoch += 1;
        }
    }

    /// Drain the count of blocks LRU-evicted from the tree since the last
    /// call (feeds the `prefix_evict` journal event).
    pub fn take_prefix_evicted(&mut self) -> u32 {
        self.prefix
            .as_mut()
            .map_or(0, |px| std::mem::take(&mut px.evicted_pending))
    }

    /// Sanity invariant (checked in tests): every block is either free or
    /// owned by exactly one request, block 0 is owned by nobody, the cached
    /// rows agree with the authoritative block lists, and the id side index
    /// agrees with the slab (same population, handle→rid→handle closes).
    ///
    /// With the prefix cache armed the exclusive-ownership sweep
    /// generalizes to refcount accounting (ISSUE 10): the observed owner
    /// count of every block (occurrences across request block lists + tree
    /// nodes holding it) must equal its refcount, a block is on the free
    /// list iff that count is 0 (refcounted + free partition the pool), no
    /// request lists a block twice, the tree is a well-formed forest
    /// (parent/child links close, one node per block, trash never cached,
    /// every node's block refcount ≥ 1 — a refcount-0 node, interior or
    /// leaf, is a structural error), and refcounts never grow down a chain
    /// (sharers adopt prefixes from the root, so parent ≥ child).
    pub fn check_invariants(&self) -> Result<()> {
        let n = self.cfg.n_blocks;
        let mut owners = vec![0u32; n];
        let mut in_free = vec![false; n];
        for &b in &self.free {
            if b == TRASH_BLOCK {
                bail!("trash block on free list");
            }
            if in_free[b as usize] {
                bail!("block {b} double-tracked (free list)");
            }
            in_free[b as usize] = true;
        }
        let mut n_live = 0usize;
        for (h, req) in self.requests.iter() {
            n_live += 1;
            let rid = req.rid;
            // Handle/id agreement: the side index must map this entry's rid
            // back to exactly this handle.
            match self.by_id.get(&rid) {
                Some(&hid) if hid == h => {}
                Some(_) => bail!("request {rid}: side index maps to a different handle"),
                None => bail!("request {rid}: live in slab but missing from side index"),
            }
            let bt = self.cfg.block_tokens(req.layout_p);
            if req.seq_len > req.blocks.len() * bt {
                bail!("request {rid} seq_len beyond capacity");
            }
            let mut within = std::collections::BTreeSet::new();
            for &b in &req.blocks {
                if b == TRASH_BLOCK {
                    bail!("request {rid} owns trash block");
                }
                if !within.insert(b) {
                    bail!("request {rid} lists block {b} twice");
                }
                owners[b as usize] += 1;
            }
            // The incrementally-maintained row cache must agree with the
            // authoritative block list at all times.
            if req.row.len() != self.cfg.n_blocks {
                bail!("request {rid} row cache has wrong width");
            }
            for (i, &cell) in req.row.iter().enumerate() {
                let want = req.blocks.get(i).map(|&b| b as i32).unwrap_or(TRASH_BLOCK as i32);
                if cell != want {
                    bail!("request {rid} row cache stale at {i}: {cell} != {want}");
                }
            }
        }
        if n_live != self.by_id.len() {
            bail!(
                "side index size {} != live slab entries {n_live}",
                self.by_id.len()
            );
        }
        for (&rid, &h) in &self.by_id {
            match self.requests.get(h) {
                Some(req) if req.rid == rid => {}
                _ => bail!("side index entry {rid} points at a stale handle"),
            }
        }
        match &self.prefix {
            None => {
                // Exclusive ownership: every block free xor owned by
                // exactly one request (the PR-1..9 invariant, unchanged).
                for b in 1..n {
                    match (owners[b], in_free[b]) {
                        (0, true) | (1, false) => {}
                        (0, false) => bail!("leaked block {b} (neither free nor owned)"),
                        (_, true) => bail!("block {b} both free and owned"),
                        (_, false) => bail!("block {b} double-owned"),
                    }
                }
            }
            Some(px) => {
                if px.refcounts.len() != n || px.node_of_block.len() != n {
                    bail!("prefix pool index vectors have wrong width");
                }
                let bt1 = self.cfg.block_tokens(1);
                let mut in_edges = vec![0u32; px.nodes.len()];
                for r in &px.roots {
                    in_edges[*r as usize] += 1;
                }
                for (i, node) in px.nodes.iter().enumerate() {
                    if !node.live {
                        continue;
                    }
                    if node.block == TRASH_BLOCK {
                        bail!("prefix node {i} caches the trash block");
                    }
                    if node.tokens.len() != bt1 {
                        bail!("prefix node {i} is not one DP block of tokens");
                    }
                    owners[node.block as usize] += 1;
                    if px.node_of_block[node.block as usize] != i as u32 {
                        bail!("block {} -> node map disagrees with node {i}", node.block);
                    }
                    match node.parent {
                        NO_NODE => {
                            if !px.roots.contains(&(i as u32)) {
                                bail!("prefix node {i} is parentless but not a root");
                            }
                        }
                        p => {
                            let parent = &px.nodes[p as usize];
                            if !parent.live || !parent.children.contains(&(i as u32)) {
                                bail!("prefix node {i} has a broken parent link");
                            }
                        }
                    }
                    for &c in &node.children {
                        let child = &px.nodes[c as usize];
                        if !child.live || child.parent != i as u32 {
                            bail!("prefix node {i} has a broken child link {c}");
                        }
                        in_edges[c as usize] += 1;
                    }
                }
                for (i, node) in px.nodes.iter().enumerate() {
                    let want = u32::from(node.live);
                    if in_edges[i] != want {
                        bail!("prefix node {i} referenced {} times (want {want})", in_edges[i]);
                    }
                }
                // Refcount cross-check: observed owners == refcount,
                // free ⟺ refcount 0, every non-trash block accounted.
                for b in 1..n {
                    if px.refcounts[b] != owners[b] {
                        bail!(
                            "block {b} refcount drift: counted {} owners, refcount {}",
                            owners[b],
                            px.refcounts[b]
                        );
                    }
                    if in_free[b] && owners[b] != 0 {
                        bail!("block {b} both free and refcounted");
                    }
                    if !in_free[b] && owners[b] == 0 {
                        bail!("leaked block {b} (refcount 0 but not free)");
                    }
                }
                // Monotone chains: a node's block can never be more shared
                // than its parent's (adoption always starts at the root).
                for node in px.nodes.iter().filter(|n| n.live) {
                    if node.parent != NO_NODE {
                        let pb = px.nodes[node.parent as usize].block as usize;
                        if px.refcounts[pb] < px.refcounts[node.block as usize] {
                            bail!(
                                "prefix chain refcount inversion at block {}",
                                node.block
                            );
                        }
                    }
                }
                // node_of_block reverse closure.
                for (b, &idx) in px.node_of_block.iter().enumerate() {
                    if idx != NO_NODE {
                        let node = &px.nodes[idx as usize];
                        if !node.live || node.block as usize != b {
                            bail!("block {b} -> node map points at a dead/foreign node");
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 8,
            ffn_hidden: 48,
            n_experts: 0,
            top_k: 0,
            n_blocks: 16,
            block_base: 4,
            max_ctx: 256,
            vocab: 258,
            pool_elems: 16 * 4 * 4 * 8,
        }
    }

    #[test]
    fn slot_mapping_dp() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 9).unwrap(); // 3 blocks of 4 tokens
        let blocks = a.request(1).unwrap().blocks.clone();
        assert_eq!(blocks.len(), 3);
        assert_eq!(a.slot(1, 0).unwrap(), blocks[0] * 4);
        assert_eq!(a.slot(1, 5).unwrap(), blocks[1] * 4 + 1);
        assert_eq!(a.slot(1, 8).unwrap(), blocks[2] * 4);
        assert!(a.slot(1, 12).is_err());
    }

    #[test]
    fn slot_mapping_respects_layout() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 2).unwrap(); // B(2) = 8 tokens per block
        a.ensure_capacity(1, 9).unwrap();
        assert_eq!(a.request(1).unwrap().blocks.len(), 2);
        let b = a.request(1).unwrap().blocks.clone();
        assert_eq!(a.slot(1, 7).unwrap(), b[0] * 8 + 7);
        assert_eq!(a.slot(1, 8).unwrap(), b[1] * 8);
    }

    #[test]
    fn handle_paths_agree_with_id_paths() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(7, 1).unwrap();
        assert_eq!(a.handle_of(7), Some(h));
        a.ensure_capacity_h(h, 9).unwrap();
        a.set_seq_len_h(h, 9).unwrap();
        for pos in 0..9 {
            assert_eq!(a.slot_h(h, pos).unwrap(), a.slot(7, pos).unwrap());
        }
        assert_eq!(a.table_row_ref_h(h).unwrap(), a.table_row_ref(7).unwrap());
        a.check_invariants().unwrap();
        a.release_h(h).unwrap();
        // Every copy of the handle is dead after release; the id is free for
        // re-registration and gets a fresh handle.
        assert!(a.slot_h(h, 0).is_err());
        assert!(a.table_row_ref_h(h).is_err());
        let h2 = a.register(7, 2).unwrap();
        assert_ne!(h, h2);
        assert!(a.request_h(h).is_none());
        a.check_invariants().unwrap();
    }

    #[test]
    fn stale_handle_does_not_alias_reused_slot() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 4).unwrap();
        a.release_h(h1).unwrap();
        // New registration reuses the slab slot; the old handle must not
        // see it.
        let h2 = a.register(2, 1).unwrap();
        assert_eq!(h1.index(), h2.index());
        assert!(a.request_h(h1).is_none());
        assert!(a.slot_h(h1, 0).is_err());
        a.check_invariants().unwrap();
    }

    #[test]
    fn oom_is_clean_and_state_preserving() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        // 15 usable blocks * 4 tokens = 60 tokens max.
        assert!(a.ensure_capacity(1, 60).is_ok());
        assert_eq!(a.free_blocks(), 0);
        a.register(2, 1).unwrap();
        assert!(a.ensure_capacity(2, 1).is_err());
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert!(a.ensure_capacity(2, 1).is_ok());
        a.check_invariants().unwrap();
    }

    #[test]
    fn capacity_grows_with_layout_tp4() {
        let c = cfg();
        let mut a = KvCacheAdaptor::new(c.clone());
        a.register(1, 4).unwrap();
        // Under 4TP one request can cache 15 * 16 = 240 tokens.
        assert!(a.ensure_capacity(1, c.tp_token_capacity(4)).is_ok());
        assert!(a.ensure_capacity(1, c.tp_token_capacity(4) + 1).is_err());
    }

    #[test]
    fn hard_preempt_pause_keeps_blocks() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 10).unwrap();
        a.set_seq_len(1, 10).unwrap();
        let before = a.request(1).unwrap().blocks.clone();
        a.pause(1).unwrap();
        // A TP request arrives and allocates from the same pool.
        a.register(2, 2).unwrap();
        a.ensure_capacity(2, 20).unwrap();
        assert_eq!(a.request(1).unwrap().blocks, before);
        assert_eq!(a.request(1).unwrap().seq_len, 10);
        a.resume(1).unwrap();
        assert!(!a.request(1).unwrap().paused);
        a.check_invariants().unwrap();
    }

    #[test]
    fn soft_preempt_relayout_frees_and_retags() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(1, 1).unwrap();
        a.ensure_capacity(1, 12).unwrap();
        a.set_seq_len(1, 12).unwrap();
        let free_before = a.free_blocks();
        let recompute = a.relayout_for_recompute(1, 4).unwrap();
        assert_eq!(recompute, 12);
        assert_eq!(a.request(1).unwrap().layout_p, 4);
        assert_eq!(a.request(1).unwrap().seq_len, 0);
        assert_eq!(a.free_blocks(), free_before + 3);
        // Relayout keeps the registration: the handle survives.
        assert!(a.request_h(h).is_some());
        a.check_invariants().unwrap();
    }

    #[test]
    fn table_row_pads_with_trash() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 5).unwrap();
        let row = a.table_row(1).unwrap();
        assert_eq!(row.len(), cfg().n_blocks);
        assert!(row[2..].iter().all(|&b| b == TRASH_BLOCK as i32));
        assert!(row[0] != TRASH_BLOCK as i32 && row[1] != TRASH_BLOCK as i32);
    }

    #[test]
    fn table_row_ref_is_borrowed_and_incremental() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 5).unwrap(); // 2 blocks
        let snapshot: Vec<i32> = a.table_row_ref(1).unwrap().to_vec();
        assert_eq!(snapshot, a.table_row(1).unwrap());
        // Growing must extend the cached row in place, not rebuild it.
        a.ensure_capacity(1, 13).unwrap(); // 4 blocks
        let row = a.table_row_ref(1).unwrap();
        assert_eq!(row.len(), cfg().n_blocks);
        assert_eq!(&row[..2], &snapshot[..2], "existing prefix must be stable");
        assert!(row[2] != TRASH_BLOCK as i32 && row[3] != TRASH_BLOCK as i32);
        assert!(row[4..].iter().all(|&b| b == TRASH_BLOCK as i32));
        a.check_invariants().unwrap();
    }

    #[test]
    fn relayout_resets_cached_row() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 12).unwrap();
        a.set_seq_len(1, 12).unwrap();
        a.relayout_for_recompute(1, 2).unwrap();
        assert!(a
            .table_row_ref(1)
            .unwrap()
            .iter()
            .all(|&b| b == TRASH_BLOCK as i32));
        // Re-grow under the new layout repopulates from the front.
        a.ensure_capacity(1, 9).unwrap(); // 2 blocks of 8 under p=2
        let row = a.table_row_ref(1).unwrap();
        assert!(row[0] != TRASH_BLOCK as i32 && row[1] != TRASH_BLOCK as i32);
        assert!(row[2..].iter().all(|&b| b == TRASH_BLOCK as i32));
        a.check_invariants().unwrap();
    }

    #[test]
    fn mode_switch_is_metadata_only() {
        let a = KvCacheAdaptor::new(cfg());
        assert_eq!(a.switch_mode_metadata_cost(), 0);
    }

    // -----------------------------------------------------------------
    // Layout-preserving migration (ISSUE 4)
    // -----------------------------------------------------------------

    #[test]
    fn migration_promote_retags_prefix_in_place() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h, 12).unwrap(); // 3 blocks of 4 tokens
        a.set_seq_len_h(h, 12).unwrap();
        let before = a.request_h(h).unwrap().blocks.clone();
        let free_before = a.free_blocks();
        let mut plan = MigrationPlan::default();
        a.plan_migration(h, 2, &mut plan).unwrap();
        // 12 tokens under B(2)=8 need 2 blocks: keep 2, free 1, move the
        // peer's half-width slice only.
        assert_eq!(plan.retag, &before[..2]);
        assert_eq!(plan.free, &before[2..]);
        assert_eq!(plan.grow, 0);
        assert_eq!(plan.peer_blocks, 2);
        assert_eq!(plan.elems_per_member, 12 * cfg().kv_width(2));
        assert_eq!(plan.link_bytes, 4 * plan.elems_per_member);
        a.apply_migration(h, &plan).unwrap();
        let req = a.request_h(h).unwrap();
        assert_eq!(req.layout_p, 2);
        assert_eq!(req.seq_len, 12, "migration must not lose cached tokens");
        assert_eq!(req.blocks, &before[..2], "kept blocks re-tagged in place");
        assert_eq!(a.free_blocks(), free_before + 1);
        // Every cached position still resolves to a slot under the new
        // layout (token coverage preserved).
        for pos in 0..12 {
            a.slot_h(h, pos).unwrap();
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn migration_demote_grows_from_pool() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(1, 4).unwrap();
        a.ensure_capacity_h(h, 20).unwrap(); // 2 blocks of 16 tokens
        a.set_seq_len_h(h, 20).unwrap();
        let before = a.request_h(h).unwrap().blocks.clone();
        let free_before = a.free_blocks();
        let mut plan = MigrationPlan::default();
        a.plan_migration(h, 1, &mut plan).unwrap();
        // 20 tokens under B(1)=4 need 5 blocks: keep both, grow 3 (the
        // gather direction — the DP target collects the slices it lacks).
        assert_eq!(plan.retag, before);
        assert!(plan.free.is_empty());
        assert_eq!(plan.grow, 3);
        assert_eq!(plan.elems_per_member, 20 * cfg().kv_width(4));
        a.apply_migration(h, &plan).unwrap();
        let req = a.request_h(h).unwrap();
        assert_eq!(req.layout_p, 1);
        assert_eq!(req.seq_len, 20);
        assert_eq!(req.blocks.len(), 5);
        assert_eq!(&req.blocks[..2], &before[..]);
        assert_eq!(a.free_blocks(), free_before - 3);
        for pos in 0..20 {
            a.slot_h(h, pos).unwrap();
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn migration_oom_fails_cleanly_without_mutation() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(1, 4).unwrap();
        a.ensure_capacity_h(h, 64).unwrap(); // 4 blocks of 16
        a.set_seq_len_h(h, 64).unwrap();
        // Exhaust the pool with a second request.
        a.register(2, 1).unwrap();
        a.ensure_capacity(2, 11 * 4).unwrap();
        assert_eq!(a.free_blocks(), 0);
        // 64 tokens at p=1 need 16 blocks (> 4 held): the grow cannot be
        // supplied, the plan must fail, and nothing may change.
        let mut plan = MigrationPlan::default();
        assert!(a.plan_migration(h, 1, &mut plan).is_err());
        let req = a.request_h(h).unwrap();
        assert_eq!(req.layout_p, 4);
        assert_eq!(req.seq_len, 64);
        a.check_invariants().unwrap();
    }

    #[test]
    fn stale_migration_plan_is_rejected() {
        let mut a = KvCacheAdaptor::new(cfg());
        let h = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h, 8).unwrap();
        a.set_seq_len_h(h, 8).unwrap();
        let mut plan = MigrationPlan::default();
        a.plan_migration(h, 2, &mut plan).unwrap();
        // State moves between plan and apply: the apply must refuse.
        a.ensure_capacity_h(h, 16).unwrap();
        a.set_seq_len_h(h, 16).unwrap();
        assert!(a.apply_migration(h, &plan).is_err());
        a.check_invariants().unwrap();
    }

    #[test]
    fn prop_migration_conserves_blocks_and_coverage() {
        // ISSUE 4 conservation property: across random grow/migrate
        // sequences, every source block is mapped exactly once (re-tagged
        // prefix + freed tail partition the old list), byte totals are
        // preserved (pool delta == free.len() - grow), token coverage
        // survives every hop, and no free block is double-used
        // (check_invariants' exclusive-ownership sweep).
        prop_check("kv migration conservation", 120, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            // Extended (ISSUE 10): half the cases run with the prefix cache
            // armed — with no sharing in play, refcounted accounting must
            // reproduce exclusive-ownership behavior exactly.
            if g.usize(0, 1) == 1 {
                a.enable_prefix_cache();
            }
            let mut plan = MigrationPlan::default();
            let p0 = *g.choose(&[1usize, 2, 4]);
            let h = a.register(1, p0).map_err(|e| e.to_string())?;
            // A second request keeps pool pressure realistic.
            a.register(2, 1).map_err(|e| e.to_string())?;
            let _ = a.ensure_capacity(2, g.usize(0, 24));
            for _ in 0..g.usize(1, 8) {
                let cur_p = a.request_h(h).unwrap().layout_p;
                let want = g.usize(0, c.tp_token_capacity(cur_p).min(60));
                if a.ensure_capacity_h(h, want).is_ok() {
                    let cap =
                        a.request_h(h).unwrap().blocks.len() * c.block_tokens(cur_p);
                    a.set_seq_len_h(h, want.min(cap)).map_err(|e| e.to_string())?;
                }
                let new_p = *g.choose(&[1usize, 2, 4]);
                let before = a.request_h(h).unwrap().blocks.clone();
                let free_before = a.free_blocks();
                let seq = a.request_h(h).unwrap().seq_len;
                if a.plan_migration(h, new_p, &mut plan).is_err() {
                    continue; // demote OOM: state must be untouched
                }
                // Partition: retag ++ free == the old block list, exactly.
                let mut mapped = plan.retag.clone();
                mapped.extend_from_slice(&plan.free);
                crate::prop_assert_eq!(mapped, before);
                a.apply_migration(h, &plan).map_err(|e| e.to_string())?;
                let req = a.request_h(h).unwrap();
                crate::prop_assert_eq!(req.layout_p, new_p);
                crate::prop_assert_eq!(req.seq_len, seq);
                crate::prop_assert_eq!(
                    req.blocks.len(),
                    plan.retag.len() + plan.grow
                );
                // Byte totals: pool delta matches the plan's free/grow.
                crate::prop_assert_eq!(
                    a.free_blocks() as i64,
                    free_before as i64 + plan.free.len() as i64 - plan.grow as i64
                );
                // Token coverage preserved under the new layout.
                for pos in (0..seq).step_by(3) {
                    crate::prop_assert!(
                        a.slot_h(h, pos).is_ok(),
                        "position {pos} lost by migration to p={new_p}"
                    );
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pool_never_double_allocates() {
        prop_check("kv pool exclusive ownership", 150, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            let mut live: Vec<u64> = Vec::new();
            let mut next_rid = 0u64;
            for _ in 0..g.usize(1, 60) {
                match g.usize(0, 3) {
                    0 => {
                        let p = *g.choose(&[1usize, 2, 4]);
                        next_rid += 1;
                        a.register(next_rid, p).map_err(|e| e.to_string())?;
                        live.push(next_rid);
                    }
                    1 if !live.is_empty() => {
                        let rid = *g.choose(&live);
                        let want = g.usize(0, 80);
                        let _ = a.ensure_capacity(rid, want); // OOM allowed
                    }
                    2 if !live.is_empty() => {
                        let i = g.raw_usize(0, live.len() - 1);
                        let rid = live.swap_remove(i);
                        a.release(rid).map_err(|e| e.to_string())?;
                    }
                    3 if !live.is_empty() => {
                        let rid = *g.choose(&live);
                        let p = *g.choose(&[1usize, 2, 4]);
                        let _ = a.relayout_for_recompute(rid, p);
                    }
                    _ => {}
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_slots_unique_within_request() {
        prop_check("slots unique per (rid,pos)", 60, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            let p = *g.choose(&[1usize, 2, 4]);
            a.register(1, p).map_err(|e| e.to_string())?;
            let n = g.usize(1, c.tp_token_capacity(p).min(100));
            a.ensure_capacity(1, n).map_err(|e| e.to_string())?;
            let mut seen = std::collections::BTreeSet::new();
            for pos in 0..n {
                let s = a.slot(1, pos).map_err(|e| e.to_string())?;
                crate::prop_assert!(seen.insert(s), "slot {s} repeated at pos {pos}");
                // Slot must lie inside the pool and outside the trash block.
                let bt = c.block_tokens(p) as u32;
                crate::prop_assert!(s >= bt, "slot {s} inside trash block");
                crate::prop_assert!(
                    (s as usize) < c.n_blocks * c.block_tokens(p),
                    "slot {s} out of pool"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mixed_layouts_disjoint_physical_ranges() {
        // DP- and TP-layout requests in one pool must map to disjoint
        // physical byte ranges (Hard Preempt coexistence).
        prop_check("mixed layouts disjoint", 60, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            a.register(1, 1).map_err(|e| e.to_string())?;
            a.register(2, *g.choose(&[2usize, 4])).map_err(|e| e.to_string())?;
            let n1 = g.usize(1, 20);
            let n2 = g.usize(1, 20);
            a.ensure_capacity(1, n1).map_err(|e| e.to_string())?;
            a.ensure_capacity(2, n2).map_err(|e| e.to_string())?;
            // Physical range of a block is the same regardless of layout
            // (Eq. 2), so block-id disjointness == byte disjointness.
            let b1: std::collections::BTreeSet<u32> =
                a.request(1).unwrap().blocks.iter().copied().collect();
            let b2: std::collections::BTreeSet<u32> =
                a.request(2).unwrap().blocks.iter().copied().collect();
            crate::prop_assert!(b1.is_disjoint(&b2), "block overlap");
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // Cross-request prefix sharing (ISSUE 10)
    // -----------------------------------------------------------------

    /// `prefix_len` shared tokens followed by a tail unique to `salt`.
    fn family_prompt(prefix_len: usize, total: usize, salt: i32) -> Vec<i32> {
        (0..total)
            .map(|i| {
                if i < prefix_len {
                    i as i32
                } else {
                    1000 + salt * 100 + i as i32
                }
            })
            .collect()
    }

    #[test]
    fn prefix_probe_is_zero_when_disabled_or_cold() {
        let mut a = KvCacheAdaptor::new(cfg());
        let t = family_prompt(8, 12, 0);
        assert_eq!(a.prefix_probe(&t), 0, "disabled cache must never hit");
        a.enable_prefix_cache();
        assert_eq!(a.prefix_probe(&t), 0, "cold cache must never hit");
        assert!(a.prefix_enabled());
        a.check_invariants().unwrap();
    }

    #[test]
    fn prefix_donate_then_adopt_shares_blocks() {
        let mut a = KvCacheAdaptor::new(cfg()); // bt(1) = 4
        a.enable_prefix_cache();
        let t1 = family_prompt(8, 12, 1);
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 12).unwrap();
        a.set_seq_len_h(h1, 12).unwrap();
        let donor_blocks = a.request_h(h1).unwrap().blocks.clone();
        assert_eq!(a.prefix_donate(h1, &t1).unwrap(), 3, "3 novel full blocks");
        let free_before = a.free_blocks();
        a.release_h(h1).unwrap();
        // The tree keeps every donated block alive past the donor.
        assert_eq!(a.free_blocks(), free_before, "donated blocks must not free");
        assert_eq!(a.prefix_cached_blocks(), 3);
        a.check_invariants().unwrap();

        // A same-family request matches the shared 8 tokens, not the tail.
        let t2 = family_prompt(8, 12, 2);
        assert_eq!(a.prefix_probe(&t2), 8);
        let h2 = a.register(2, 1).unwrap();
        a.prefix_adopt(h2, &t2, 8).unwrap();
        let req2 = a.request_h(h2).unwrap();
        assert_eq!(req2.seq_len, 8, "adopted tokens count as cached");
        assert_eq!(req2.blocks, &donor_blocks[..2], "prefix reused by reference");
        assert_eq!(a.table_row_ref_h(h2).unwrap()[0], donor_blocks[0] as i32);
        assert_eq!(a.table_row_ref_h(h2).unwrap()[1], donor_blocks[1] as i32);
        a.check_invariants().unwrap();
        // Growing past the adopted prefix allocates only novel blocks.
        a.ensure_capacity_h(h2, 12).unwrap();
        a.set_seq_len_h(h2, 12).unwrap();
        let req2 = a.request_h(h2).unwrap();
        assert_eq!(req2.blocks.len(), 3);
        assert!(!donor_blocks.contains(&req2.blocks[2]));
        // Finishing forks copy-on-write: only the divergent tail block
        // inserts a node; the shared chain is never duplicated.
        assert_eq!(a.prefix_donate(h2, &t2).unwrap(), 1);
        assert_eq!(a.prefix_cached_blocks(), 4);
        a.release_h(h2).unwrap();
        a.check_invariants().unwrap();
        // Full family prefix now probes end-to-end for both tails.
        assert_eq!(a.prefix_probe(&t1), 12);
        assert_eq!(a.prefix_probe(&t2), 12);
    }

    #[test]
    fn prefix_adopt_requires_fresh_dp_registration() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.enable_prefix_cache();
        let t = family_prompt(8, 12, 1);
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 12).unwrap();
        a.set_seq_len_h(h1, 12).unwrap();
        a.prefix_donate(h1, &t).unwrap();
        // Already holds blocks: not a fresh registration.
        assert!(a.prefix_adopt(h1, &t, 8).is_err());
        // TP registrations cannot adopt (nodes are DP layout).
        let h2 = a.register(2, 2).unwrap();
        assert!(a.prefix_adopt(h2, &t, 8).is_err());
        // Unaligned adoption is rejected.
        let h3 = a.register(3, 1).unwrap();
        assert!(a.prefix_adopt(h3, &t, 6).is_err());
        a.check_invariants().unwrap();
    }

    #[test]
    fn prefix_eviction_yields_cache_only_blocks_under_pressure() {
        let mut a = KvCacheAdaptor::new(cfg()); // 15 usable blocks
        a.enable_prefix_cache();
        let t = family_prompt(12, 12, 1);
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 12).unwrap();
        a.set_seq_len_h(h1, 12).unwrap();
        a.prefix_donate(h1, &t).unwrap();
        a.release_h(h1).unwrap();
        assert_eq!(a.free_blocks(), 12);
        assert_eq!(a.prefix_cached_blocks(), 3);
        // Demand for the whole pool evicts the cache leaf-first: the cache
        // borrows capacity, allocation pressure always wins.
        let h2 = a.register(2, 1).unwrap();
        a.ensure_capacity_h(h2, 60).unwrap(); // all 15 blocks
        assert_eq!(a.free_blocks(), 0);
        assert_eq!(a.prefix_cached_blocks(), 0);
        assert_eq!(a.take_prefix_evicted(), 3);
        assert_eq!(a.take_prefix_evicted(), 0, "drain is one-shot");
        assert_eq!(a.prefix_probe(&t), 0, "evicted entries no longer match");
        a.check_invariants().unwrap();
        // Still-short demand fails cleanly with nothing left to evict.
        a.register(3, 1).unwrap();
        assert!(a.ensure_capacity(3, 1).is_err());
        a.check_invariants().unwrap();
    }

    #[test]
    fn prefix_shared_blocks_are_not_evictable() {
        let mut a = KvCacheAdaptor::new(cfg());
        a.enable_prefix_cache();
        let t = family_prompt(12, 12, 1);
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 12).unwrap();
        a.set_seq_len_h(h1, 12).unwrap();
        a.prefix_donate(h1, &t).unwrap();
        a.release_h(h1).unwrap();
        // An adopter pins the first two blocks (refcount 2); the third
        // stays cache-only (refcount 1, evictable).
        let t2 = family_prompt(8, 12, 2);
        let h2 = a.register(2, 1).unwrap();
        a.prefix_adopt(h2, &t2, 8).unwrap();
        assert_eq!(a.free_blocks(), 12);
        let h3 = a.register(3, 1).unwrap();
        a.ensure_capacity_h(h3, 13 * 4).unwrap(); // 13 blocks: evicts the leaf
        assert_eq!(a.take_prefix_evicted(), 1);
        assert_eq!(a.prefix_cached_blocks(), 2);
        // The shared chain is pinned: no further eviction is possible.
        assert!(a.ensure_capacity_h(h3, 14 * 4).is_err());
        a.check_invariants().unwrap();
        // Once the sharer leaves, the chain becomes cache-only again and
        // eviction cascades parent-ward (children first).
        a.release_h(h2).unwrap();
        a.ensure_capacity_h(h3, 15 * 4).unwrap();
        assert_eq!(a.take_prefix_evicted(), 2);
        assert_eq!(a.prefix_cached_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn migration_scatters_shared_prefix_once_per_epoch() {
        let c = cfg();
        let mut a = KvCacheAdaptor::new(c.clone());
        a.enable_prefix_cache();
        // Seed the family: donor writes 2 shared blocks + 1 unique.
        let t1 = family_prompt(8, 12, 1);
        let h1 = a.register(1, 1).unwrap();
        a.ensure_capacity_h(h1, 12).unwrap();
        a.set_seq_len_h(h1, 12).unwrap();
        a.prefix_donate(h1, &t1).unwrap();
        a.release_h(h1).unwrap();
        // Two sharers adopt the same 8-token prefix and finish their own
        // prefill (12 tokens each: 2 shared + 1 private block).
        let t2 = family_prompt(8, 12, 2);
        let t3 = family_prompt(8, 12, 3);
        let h2 = a.register(2, 1).unwrap();
        a.prefix_adopt(h2, &t2, 8).unwrap();
        a.ensure_capacity_h(h2, 12).unwrap();
        a.set_seq_len_h(h2, 12).unwrap();
        let h3 = a.register(3, 1).unwrap();
        a.prefix_adopt(h3, &t3, 8).unwrap();
        a.ensure_capacity_h(h3, 12).unwrap();
        a.set_seq_len_h(h3, 12).unwrap();
        let shared: Vec<u32> = a.request_h(h2).unwrap().blocks[..2].to_vec();
        assert_eq!(&a.request_h(h3).unwrap().blocks[..2], &shared[..]);
        // Both sharers promote to p=2 inside one switch epoch.
        a.begin_switch_epoch();
        let mut plan = MigrationPlan::default();
        a.plan_migration(h2, 2, &mut plan).unwrap();
        assert_eq!(plan.retag, shared);
        assert_eq!(plan.free.len(), 1);
        assert_eq!(
            plan.elems_per_member,
            12 * c.kv_width(2),
            "first sharer scatters its full sequence"
        );
        a.apply_migration(h2, &plan).unwrap();
        a.check_invariants().unwrap();
        a.plan_migration(h3, 2, &mut plan).unwrap();
        assert_eq!(plan.retag, shared, "same physical prefix re-tagged in place");
        assert_eq!(
            plan.elems_per_member,
            4 * c.kv_width(2),
            "co-migrating sharer moves only its divergent tail"
        );
        a.apply_migration(h3, &plan).unwrap();
        // Both sharers crossed the switch with their cached tokens intact:
        // nothing to re-prefill, coverage preserved under the new layout.
        for h in [h2, h3] {
            let req = a.request_h(h).unwrap();
            assert_eq!(req.layout_p, 2);
            assert_eq!(req.seq_len, 12);
            for pos in 0..12 {
                a.slot_h(h, pos).unwrap();
            }
        }
        // Migration consumed the cache entries (bytes are TP layout now).
        assert_eq!(a.prefix_probe(&t1), 0);
        a.check_invariants().unwrap();
        // A fresh epoch re-arms the full scatter cost.
        a.begin_switch_epoch();
        a.plan_migration(h2, 1, &mut plan).unwrap();
        assert_eq!(plan.elems_per_member, 12 * c.kv_width(2));
        a.check_invariants().unwrap();
    }

    #[test]
    fn prop_migration_with_sharing_maps_each_block_once() {
        // ISSUE 10 satellite: migration × sharing.  Random sharer sets over
        // one prompt family, random per-sharer migrations inside switch
        // epochs: every plan must map each of the request's blocks exactly
        // once (retag ++ free partitions the list), all sharers' seq_lens
        // survive anyone's migration, the refcount cross-check holds at
        // every safe point, and stale handles skip-never-panic.
        prop_check("kv migration x sharing", 80, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            a.enable_prefix_cache();
            let prefix_len = 4 * g.usize(1, 2); // 1–2 shared blocks
            let total = prefix_len + 4;
            // Donor seeds the family tree, then leaves.
            let t0 = family_prompt(prefix_len, total, 0);
            let h0 = a.register(1000, 1).map_err(|e| e.to_string())?;
            a.ensure_capacity_h(h0, total).map_err(|e| e.to_string())?;
            a.set_seq_len_h(h0, total).map_err(|e| e.to_string())?;
            a.prefix_donate(h0, &t0).map_err(|e| e.to_string())?;
            a.release_h(h0).map_err(|e| e.to_string())?;
            a.check_invariants().map_err(|e| e.to_string())?;
            // Sharers adopt the family prefix and finish prefill.
            let n_share = g.usize(1, 3);
            let mut live: Vec<(u64, KvHandle, usize)> = Vec::new(); // rid, h, seq
            for s in 0..n_share {
                let rid = s as u64 + 1;
                let t = family_prompt(prefix_len, total, s as i32 + 1);
                let h = a.register(rid, 1).map_err(|e| e.to_string())?;
                let hit = a.prefix_probe(&t).min(prefix_len);
                a.prefix_adopt(h, &t, hit).map_err(|e| e.to_string())?;
                if a.ensure_capacity_h(h, total).is_ok() {
                    a.set_seq_len_h(h, total).map_err(|e| e.to_string())?;
                    live.push((rid, h, total));
                } else {
                    a.release_h(h).map_err(|e| e.to_string())?;
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            let mut plan = MigrationPlan::default();
            for _ in 0..g.usize(1, 6) {
                if live.is_empty() {
                    break;
                }
                match g.usize(0, 2) {
                    0 => a.begin_switch_epoch(),
                    1 => {
                        let i = g.raw_usize(0, live.len() - 1);
                        let (_, h, seq) = live[i];
                        let new_p = *g.choose(&[1usize, 2]);
                        let before = match a.request_h(h) {
                            Some(r) => r.blocks.clone(),
                            None => continue,
                        };
                        if a.plan_migration(h, new_p, &mut plan).is_err() {
                            continue;
                        }
                        // Exactly-once mapping: retag ++ free == old list.
                        let mut mapped = plan.retag.clone();
                        mapped.extend_from_slice(&plan.free);
                        crate::prop_assert_eq!(mapped, before);
                        a.apply_migration(h, &plan).map_err(|e| e.to_string())?;
                        let req = a.request_h(h).unwrap();
                        crate::prop_assert_eq!(req.seq_len, seq);
                        crate::prop_assert_eq!(req.layout_p, new_p);
                        // The migrating sharer's coverage survives...
                        for pos in (0..seq).step_by(3) {
                            crate::prop_assert!(a.slot_h(h, pos).is_ok());
                        }
                    }
                    2 => {
                        let i = g.raw_usize(0, live.len() - 1);
                        let (_, h, _) = live.swap_remove(i);
                        crate::prop_assert!(a.release_if_live_h(h));
                        // Stale handle: second release skips, never panics.
                        crate::prop_assert!(!a.release_if_live_h(h));
                    }
                    _ => {}
                }
                // ...and so does every *other* sharer's, untouched.
                for &(_, h, seq) in &live {
                    let req = match a.request_h(h) {
                        Some(r) => r,
                        None => return Err("live sharer lost its handle".into()),
                    };
                    crate::prop_assert_eq!(req.seq_len, seq);
                    for pos in (0..seq).step_by(3) {
                        crate::prop_assert!(a.slot_h(h, pos).is_ok());
                    }
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            for (_, h, _) in live {
                a.release_if_live_h(h);
            }
            a.check_invariants().map_err(|e| e.to_string())?;
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // Slab-vs-BTreeMap oracle: drive the slab-backed adaptor and a naive
    // BTreeMap model through the same randomized op sequence and demand
    // observational equality on every query surface (ISSUE 3 satellite).
    // -----------------------------------------------------------------

    /// The pre-slab adaptor's semantics, restated as a trivially-correct
    /// BTreeMap model (block grants replayed from a shared free-list
    /// discipline so physical ids match the adaptor's exactly).
    struct MapModel {
        cfg: ModelCfg,
        free: Vec<u32>,
        reqs: std::collections::BTreeMap<u64, (usize, Vec<u32>, usize)>, // p, blocks, seq_len
    }

    impl MapModel {
        fn new(cfg: ModelCfg) -> Self {
            let free = (1..cfg.n_blocks as u32).rev().collect();
            MapModel { cfg, free, reqs: Default::default() }
        }

        fn register(&mut self, rid: u64, p: usize) -> Result<(), String> {
            if self.reqs.contains_key(&rid) {
                return Err("already registered".into());
            }
            self.reqs.insert(rid, (p, Vec::new(), 0));
            Ok(())
        }

        fn ensure_capacity(&mut self, rid: u64, n: usize) -> Result<(), String> {
            let (p, blocks, _) = self.reqs.get(&rid).ok_or("not registered")?;
            let bt = self.cfg.block_tokens(*p);
            let need = n.div_ceil(bt);
            if need > self.cfg.n_blocks - 1 {
                return Err("over pool capacity".into());
            }
            let short = need.saturating_sub(blocks.len());
            if short > self.free.len() {
                return Err("pool exhausted".into());
            }
            let (_, blocks, _) = self.reqs.get_mut(&rid).unwrap();
            for _ in 0..short {
                blocks.push(self.free.pop().unwrap());
            }
            Ok(())
        }

        fn slot(&self, rid: u64, pos: usize) -> Option<u32> {
            let (p, blocks, _) = self.reqs.get(&rid)?;
            let bt = self.cfg.block_tokens(*p);
            blocks.get(pos / bt).map(|&b| b * bt as u32 + (pos % bt) as u32)
        }

        fn table_row(&self, rid: u64) -> Option<Vec<i32>> {
            let (_, blocks, _) = self.reqs.get(&rid)?;
            let mut row = vec![TRASH_BLOCK as i32; self.cfg.n_blocks];
            for (i, &b) in blocks.iter().enumerate() {
                row[i] = b as i32;
            }
            Some(row)
        }

        fn release(&mut self, rid: u64) -> Result<(), String> {
            let (_, blocks, _) = self.reqs.remove(&rid).ok_or("not registered")?;
            self.free.extend(blocks.into_iter().rev());
            Ok(())
        }
    }

    #[test]
    fn prop_slab_adaptor_matches_btreemap_oracle() {
        prop_check("slab adaptor ≡ BTreeMap oracle", 120, |g| {
            let c = cfg();
            let mut a = KvCacheAdaptor::new(c.clone());
            let mut m = MapModel::new(c.clone());
            let mut live: Vec<u64> = Vec::new();
            let mut next_rid = 0u64;
            for _ in 0..g.usize(1, 80) {
                match g.usize(0, 2) {
                    0 => {
                        let p = *g.choose(&[1usize, 2, 4]);
                        next_rid += 1;
                        let ra = a.register(next_rid, p).is_ok();
                        let rm = m.register(next_rid, p).is_ok();
                        crate::prop_assert_eq!(ra, rm);
                        if ra {
                            live.push(next_rid);
                        }
                    }
                    1 if !live.is_empty() => {
                        let rid = *g.choose(&live);
                        let want = g.usize(0, 70);
                        let ra = a.ensure_capacity(rid, want).is_ok();
                        let rm = m.ensure_capacity(rid, want).is_ok();
                        crate::prop_assert_eq!(ra, rm);
                    }
                    2 if !live.is_empty() => {
                        let i = g.raw_usize(0, live.len() - 1);
                        let rid = live.swap_remove(i);
                        a.release(rid).map_err(|e| e.to_string())?;
                        m.release(rid)?;
                    }
                    _ => {}
                }
                // Observational equality on every query surface.
                crate::prop_assert_eq!(a.free_blocks(), m.free.len());
                for &rid in &live {
                    crate::prop_assert_eq!(
                        a.table_row(rid).ok(),
                        m.table_row(rid)
                    );
                    let n_tok =
                        m.reqs[&rid].1.len() * c.block_tokens(m.reqs[&rid].0);
                    for pos in (0..n_tok).step_by(3) {
                        crate::prop_assert_eq!(a.slot(rid, pos).ok(), m.slot(rid, pos));
                    }
                    crate::prop_assert!(
                        a.slot(rid, n_tok).is_err(),
                        "slot past capacity must fail"
                    );
                }
                a.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
