//! Minimal JSON parser/serializer (serde is not in the offline crate set).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json`, the
//! config files, the TCP line-protocol, and metrics export: objects, arrays,
//! strings with escapes, numbers, bools, null.  Not performance-critical —
//! parsed once at startup or per client request, never on the step path.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free object field access with a readable error.
    pub fn field(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.field(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

impl fmt::Display for Value {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; `{n}` would emit
                    // unparseable output (empty-class metric means are NaN).
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our data; map
                            // unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Value::Num(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].str_field("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let text = orig.to_string();
        assert_eq!(Value::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse(r#""Aé""#).unwrap(),
            Value::Str("Aé".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn display_roundtrip_nested() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-3}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_string(), "null");
        // A metrics object with an empty-class NaN mean must stay parseable.
        let v = Value::obj(vec![("mean", Value::num(f64::NAN)), ("n", Value::num(0.0))]);
        let back = Value::parse(&v.to_string()).unwrap();
        assert_eq!(back.get("mean"), Some(&Value::Null));
    }

    #[test]
    fn manifest_parses() {
        // Smoke: the real manifest (when built) must parse.
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("models").is_some());
        }
    }
}
