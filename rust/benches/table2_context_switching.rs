//! Table 2 — max context support and switching latency.
//!
//! Two halves:
//!  * paper scale (Llama-70B, 8×H200 memory model): max context per static
//!    configuration, cold-restart latency, and FLYING's live switch;
//!  * real path: the live DP<->TP switch measured on the thread cluster
//!    (SetMode collective RPC + O(1) communicator-pool fetch + KV adaptor
//!    metadata re-interpretation), contrasted with an actual engine cold
//!    start (weight upload + artifact compilation).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use flying_serving::baselines::StaticDpPolicy;
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::sim::{CostModel, HwSpec, PaperModel};
use flying_serving::util::bench::Table;
use flying_serving::workload::{synth_prompt_tokens, Priority};

fn main() -> anyhow::Result<()> {
    // ---- paper scale ------------------------------------------------------
    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    let mut t = Table::new(
        "Table 2 — max context & switching latency (Llama-70B, 8xH200 model)",
        &["configuration", "GPUs/inst", "max context", "switching latency"],
    );
    for (name, g) in [("Static 4DPx2TP", 2usize), ("Static 2DPx4TP", 4), ("Static 1DPx8TP", 8)] {
        t.row(&[
            name.to_string(),
            format!("{g}"),
            format!("{} K", cm.kv_capacity_tokens(g) / 1000),
            format!("{:.2} s (cold start)", cm.cold_start_s(g)),
        ]);
    }
    t.row(&[
        "Flying Serving".into(),
        "dynamic".into(),
        format!("{:.1} M", cm.kv_capacity_tokens(8) as f64 * 0.83 / 1e6), // small fixed reservation
        format!("{:.0} ms (live)", cm.live_switch_s() * 1e3),
    ]);
    t.print();
    t.write_csv("table2_paper_scale")?;
    println!(
        "live switch is ~{:.0}x faster than the cheapest cold start",
        cm.cold_start_s(8) / cm.live_switch_s()
    );

    // ---- real path ----------------------------------------------------------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(real-path half skipped: run `make artifacts`)");
        return Ok(());
    }
    let manifest = Arc::new(Manifest::load(dir)?);

    // Cold start = what a static system pays to change parallelism.
    let t0 = Instant::now();
    let mut cluster = Cluster::start(&manifest, "llama-tiny", 2)?;
    let cold_s = t0.elapsed().as_secs_f64();

    // Live switches: drive a TP-demanding request through; the recorded
    // SwitchEvents time the SetMode RPC + communicator fetch.
    let req = ServeRequest {
        id: 1,
        prompt: synth_prompt_tokens(1, 24),
        max_new: 2,
        priority: Priority::Normal,
        tp_demand: Some(2),
        arrival: 0.0,
    };
    let mut policy = flying_serving::coordinator::policy::FlyingPolicy::default();
    let mut lat = Vec::new();
    for i in 0..20u64 {
        let mut r = req.clone();
        r.id = i + 1;
        let out = cluster.run_trace(vec![r], &mut policy, Strategy::HardPreempt)?;
        lat.extend(out.switches.iter().map(|s| s.latency_s));
    }
    // DP ground truth on the same cluster still works after all switching.
    let out = cluster.run_trace(
        vec![ServeRequest {
            id: 999,
            prompt: synth_prompt_tokens(999, 16),
            max_new: 2,
            priority: Priority::Normal,
            tp_demand: None,
            arrival: 0.0,
        }],
        &mut StaticDpPolicy,
        Strategy::Sequential,
    )?;
    assert_eq!(out.outputs[&999].len(), 2);
    cluster.shutdown();

    let mean = lat.iter().sum::<f64>() / lat.len().max(1) as f64;
    let max = lat.iter().copied().fold(0.0, f64::max);
    let mut rt = Table::new(
        "Table 2 (real path) — measured on the thread cluster (llama-tiny, 2 engines)",
        &["operation", "latency"],
    );
    rt.row(&["engine cold start (weights + compile all artifacts)".into(), format!("{cold_s:.2} s")]);
    rt.row(&[format!("live DP<->TP switch (mean of {})", lat.len()), format!("{:.3} ms", mean * 1e3)]);
    rt.row(&["live DP<->TP switch (max)".into(), format!("{:.3} ms", max * 1e3)]);
    rt.print();
    rt.write_csv("table2_real_path")?;
    println!(
        "\nreal-path live switch is ~{:.0}x faster than an engine cold start",
        cold_s / mean.max(1e-9)
    );
    Ok(())
}
