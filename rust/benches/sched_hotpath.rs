//! Serving hot-path throughput + allocation bench.
//!
//! Two halves, matching the two layers the event-core rewrite touched:
//!
//!  1. **Simulator**: drive an identical bursty synthetic trace through the
//!     event-driven `sim::simulate` and the preserved loop-based
//!     `sim::simulate_reference`, verify they agree on completion sets,
//!     rejection sets and switch counts, and report the wall-clock speedup
//!     (target: ≥5× on the 100k-request trace).
//!  2. **Coordinator**: run the real scheduler over stub engines and count
//!     heap allocations *on the coordinator thread* per `step_once`, via a
//!     thread-local counting allocator.  Steady-state decode steps must be
//!     allocation-free (median 0 allocs/step); arrival/finish edges and
//!     amortized growth (token-time buffers doubling) are reported
//!     separately as the mean.
//!
//! Two further probes (ISSUE 3):
//!
//!  3. **Switch-heavy scenarios**: priority_storm and poisson_burst traces
//!     under `SimSystem::Flying` with `switch_backfill` off vs on.
//!     Off must stay outcome-equivalent to the loop reference (hard gate);
//!     on reports *switch-stall engine-seconds* — idle capacity inside
//!     merge-transition windows — and the reduction verdict.
//!  4. **KV lookup microbench**: `slot()` through the O(1) slab handle vs
//!     through the id side-index (the pre-slab BTreeMap-shaped path), in
//!     ns/lookup.
//!
//! And the KV-migration probe (ISSUE 4):
//!
//!  5. **Zero-recompute switches**: long_context_wave and switch_churn
//!     under `SimSystem::Flying` with `switch_migrate` off vs on.  Off must
//!     stay outcome-equivalent to the loop reference (hard gate); on must
//!     carry live KV across the DP↔TP flips (`recompute_tokens_avoided > 0`,
//!     hard gate) and reports TTFT p90 off-vs-on (advisory).  The
//!     coordinator alloc probe in part 2 runs with the migrate path armed,
//!     so the zero-alloc gate covers it too.
//!
//! And the scheduling-kernel probe (ISSUE 5):
//!
//!  6. **Kernel dispatch overhead**: the same admission-decision stream
//!     through the `sched::Kernel` walk and through a hand-inlined replica
//!     of the identical semantics.  Decision-sequence equality is a hard
//!     gate (the abstraction may cost nanoseconds, never decisions); the
//!     ns/decision overhead is reported for the perf trail.  The zero-alloc
//!     coordinator gate in part 2 now also covers the kernel walk, since
//!     the coordinator routes every admission through it.
//!
//! And the fault-tolerance probes (ISSUE 6):
//!
//!  7. **Watchdog differential**: the same coordinator trace with the
//!     lockstep watchdog off (the pre-watchdog blocking path) and on with
//!     no faults injected.  Outputs, rejections, and zero fault counters
//!     must match exactly (hard gate — same discipline as the
//!     backfill-off / migrate-off gates).
//!  8. **Chaos probe**: one switch-churn trace under seeded randomized
//!     fault plans; request conservation and KV invariants are hard
//!     gates, and the fault/recovery counters land in the JSON trail.
//!  9. **Backfill-margin sweep**: `SwitchConfig::backfill_margin` over a
//!     drain-heavy ladder of elastic requests; admitted-bind counts per
//!     margin justify the tuned default (recorded in the JSON trail).
//!
//! And the flight-recorder probes (ISSUE 7):
//!
//! 10. **Stall attribution**: priority_storm and switch_churn under Flying
//!     with backfill + migrate armed; the `StallBreakdown` components must
//!     reconstruct `switch_stall_s` within 1e-9 (hard gate, in the JSON).
//!     The coordinator alloc probe in part 2 runs with `set_trace(true)`,
//!     so the zero-alloc gate also covers an armed journal.
//!
//! And the step-pipeline overlap probes (ISSUE 9):
//!
//! 11. **Overlap differential**: switch_churn and poisson_burst under
//!     Flying with `--overlap` off vs on (migrate armed on both sides so
//!     there are transfer windows to hide).  Off must stay byte-identical
//!     to the loop reference (hard gate); on reports the engine-seconds of
//!     migration hidden inside drain windows (`pipeline_overlap_s`) and
//!     the stall-reduction verdict.  The stall-attribution probe (10) now
//!     runs with overlap armed too, so its 1e-9 reconstruction gate covers
//!     the extended identity
//!     `switch_stall_s = drain_wait + settle + migration
//!                       - backfill_recovered - pipeline_overlap`.
//!     The coordinator alloc probe in part 2 arms `--overlap` as well: the
//!     double-buffered arenas are two warm slots, so the steady-state
//!     decode path must still be allocation-free (median 0 allocs/step).
//!
//! And the prefix-cache probes (ISSUE 10):
//!
//! 12. **Prefix-cache differential**: every scenario in the library under
//!     Flying with `--prefix-cache` off vs on.  Off must stay
//!     outcome-equivalent to the loop reference on *all* scenarios (hard
//!     gate — the cache must be invisible until armed); on must adopt
//!     cached prompt tokens on shared_prefix (`prefill_tokens_avoided > 0`,
//!     hard gate) and reports TTFT p90 off-vs-on (advisory).  The
//!     coordinator alloc probe in part 2 arms the prefix cache as well:
//!     armed, every block alloc/free is refcounted and every step runs the
//!     eviction drain, and the steady-state decode path must still be
//!     allocation-free (median 0 allocs/step).
//!
//! Usage:  cargo bench --bench sched_hotpath [-- --quick]
//!   --quick  : 20k-request simulator trace (CI smoke; full mode uses 100k
//!              and can take minutes in the O(n²) reference).
//!
//! Writes bench_out/sched_hotpath.json for the CI artifact trail.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeSet;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use flying_serving::baselines::StaticDpPolicy;
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::{OverlapConfig, Strategy, SwitchConfig, WatchdogConfig};
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::engine::FaultPlan;
use flying_serving::kv::KvCacheAdaptor;
use flying_serving::metrics::{FaultStats, Recorder};
use flying_serving::model::{ModelCfg, StaticShapes};
use flying_serving::sim::{
    outcomes_equivalent, simulate, simulate_reference, CostModel, HwSpec, PaperModel, SimConfig,
    SimSystem,
};
use flying_serving::util::bench::fmt_dur;
use flying_serving::workload::{generate, Priority, Scenario, WorkloadCfg};

// ---------------------------------------------------------------------------
// Thread-local counting allocator: counts allocations per thread, so engine
// worker threads (the data plane) never pollute the coordinator-thread
// measurement.
// ---------------------------------------------------------------------------

thread_local! {
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}
static TRACKING: AtomicBool = AtomicBool::new(false);

struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn bump() {
        if TRACKING.load(Ordering::Relaxed) {
            // Const-initialized TLS Cell: no lazy init, no destructor —
            // safe to touch from inside the allocator.
            ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOC_COUNT.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Part 1 — simulator: event core vs loop reference
// ---------------------------------------------------------------------------

struct SimRow {
    system: &'static str,
    new_s: f64,
    ref_s: f64,
    speedup: f64,
    equivalent: bool,
}

fn sim_compare(system: SimSystem, cm: &CostModel, trace: &[flying_serving::workload::Request]) -> SimRow {
    let cfg = SimConfig::default();

    let t0 = Instant::now();
    let new = simulate(system, cm, trace, &cfg);
    let new_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let reference = simulate_reference(system, cm, trace, &cfg);
    let ref_s = t0.elapsed().as_secs_f64();

    let equivalent = match outcomes_equivalent(&new, &reference) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("sim {}: {e}", system.label());
            false
        }
    };

    println!(
        "sim {:18} new={} ref={} speedup={:5.1}x switches={}/{} outcome-equal={}",
        system.label(),
        fmt_dur(new_s),
        fmt_dur(ref_s),
        ref_s / new_s,
        new.n_switches,
        reference.n_switches,
        equivalent,
    );
    SimRow {
        system: system.label(),
        new_s,
        ref_s,
        speedup: ref_s / new_s,
        equivalent,
    }
}

// ---------------------------------------------------------------------------
// Part 2 — coordinator: allocations per step over stub engines
// ---------------------------------------------------------------------------

fn stub_cfg() -> ModelCfg {
    ModelCfg {
        name: "hotpath-stub".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 8,
        ffn_hidden: 128,
        n_experts: 0,
        top_k: 0,
        n_blocks: 1024,
        block_base: 8,
        max_ctx: 8192,
        vocab: 258,
        pool_elems: 1024 * 8 * 4 * 8,
    }
}

struct AllocRow {
    steps: usize,
    median_allocs: u64,
    mean_allocs: f64,
    steps_per_s: f64,
}

/// Steady-state probe: N long-decode requests fully occupy every engine's
/// decode batch; once warm, each `step_once` is a pure decode iteration
/// with no arrivals and no finishes — the path the zero-allocation claim
/// is about.
fn coordinator_alloc_probe() -> anyhow::Result<AllocRow> {
    let n_engines = 4usize;
    let shapes = StaticShapes { b_dec: 16, c_prefill: 64 };
    let mut cluster = Cluster::start_stub(stub_cfg(), shapes, n_engines)?;
    // The probe runs with the migrate flag armed: this proves arming
    // `--switch-migrate` does not perturb the steady-state decode path
    // (this static-DP workload never promotes, so the migration code itself
    // is exercised by the stub-cluster e2e tests; its plan buffers live in
    // StepScratch precisely so promotions stay allocation-free too).
    cluster.set_switch_config(SwitchConfig { migrate: true, ..SwitchConfig::default() });
    // The flight recorder is armed too (ISSUE 7): its ring is allocated
    // once here, before tracking starts, and an armed-but-idle journal on
    // the steady-state decode path must record nothing and allocate
    // nothing — the same zero-alloc gate covers it.
    cluster.set_trace(true);
    // And the step pipeline (ISSUE 9): double-buffering prebuilds batch
    // N+1 into a second arena while batch N executes.  Both arenas warm up
    // during the ramp below (the prebuild slot grows once, like the front
    // slot), so with two warm slots the swap is a pointer exchange and the
    // measured steady state must stay at 0 allocs/step.
    cluster.set_overlap_config(OverlapConfig { enabled: true, ..OverlapConfig::default() });
    // And the prefix cache (ISSUE 10): armed, every block alloc/free goes
    // through the refcounted path and every measured step runs the
    // eviction drain — none of the probe's requests finishes mid-measure,
    // so the tree stays idle and the 0-alloc median gate must hold with
    // the cache armed (adoption/donation themselves live on admission/
    // finish edges, covered by the e2e suites).
    cluster.set_prefix_cache(true);
    let mut recorder = Recorder::new();
    let mut policy = StaticDpPolicy;

    let n_reqs = n_engines * shapes.b_dec; // saturate every decode batch
    let max_new = 400usize;
    for id in 0..n_reqs as u64 {
        cluster.submit(
            ServeRequest {
                id,
                prompt: vec![(id % 250) as i32; 8],
                max_new,
                priority: Priority::Normal,
                tp_demand: None,
                arrival: 0.0,
            },
            &mut recorder,
        );
    }

    // Warm up: admissions, arena growth, prefill, first decode rounds.
    for _ in 0..60 {
        cluster.step_once(&mut policy, Strategy::Sequential, &mut recorder)?;
    }

    // Measure per-step allocations on this (the coordinator) thread.
    let measured = 200usize;
    let mut per_step = Vec::with_capacity(measured);
    TRACKING.store(true, Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..measured {
        let before = thread_allocs();
        let stepped = cluster.step_once(&mut policy, Strategy::Sequential, &mut recorder)?;
        per_step.push(thread_allocs() - before);
        assert!(stepped, "probe drained early: raise max_new");
    }
    let dt = t0.elapsed().as_secs_f64();
    TRACKING.store(false, Ordering::Relaxed);
    cluster.shutdown();

    per_step.sort_unstable();
    let median = per_step[per_step.len() / 2];
    let mean = per_step.iter().sum::<u64>() as f64 / per_step.len() as f64;
    println!(
        "coordinator steady state: {} steps, allocs/step median={} mean={:.2} p99={} ({:.0} steps/s)",
        measured,
        median,
        mean,
        per_step[per_step.len() * 99 / 100],
        measured as f64 / dt,
    );
    Ok(AllocRow {
        steps: measured,
        median_allocs: median,
        mean_allocs: mean,
        steps_per_s: measured as f64 / dt,
    })
}

/// End-to-end coordinator throughput over the stub data plane, dynamic
/// policy + preemption path included (requests/sec through `run_trace`).
fn coordinator_throughput_probe() -> anyhow::Result<f64> {
    let shapes = StaticShapes { b_dec: 16, c_prefill: 64 };
    let mut cluster = Cluster::start_stub(stub_cfg(), shapes, 4)?;
    let n = 600usize;
    let trace: Vec<ServeRequest> = (0..n as u64)
        .map(|id| ServeRequest {
            id,
            prompt: vec![(id % 250) as i32; 12],
            max_new: 16,
            priority: if id % 16 == 0 { Priority::High } else { Priority::Normal },
            tp_demand: if id % 64 == 0 { Some(2) } else { None },
            arrival: 0.0,
        })
        .collect();
    let mut policy = FlyingPolicy::default();
    let t0 = Instant::now();
    let out = cluster.run_trace(trace, &mut policy, Strategy::HardPreempt)?;
    let dt = t0.elapsed().as_secs_f64();
    cluster.shutdown();
    let rps = (n - out.rejected.len()) as f64 / dt;
    println!(
        "coordinator end-to-end: {} reqs in {} ({:.0} req/s, {} steps, {} switches, {} rejected)",
        n,
        fmt_dur(dt),
        rps,
        out.n_steps,
        out.switches.len(),
        out.rejected.len(),
    );
    Ok(rps)
}

// ---------------------------------------------------------------------------
// Part 3 — switch-heavy scenarios: drain-stall elimination (ISSUE 3)
// ---------------------------------------------------------------------------

struct SwitchRow {
    scenario: &'static str,
    stall_off_s: f64,
    stall_on_s: f64,
    switches_off: usize,
    switches_on: usize,
    reclaimed_frac: f64,
    off_equivalent: bool,
}

/// Run one scenario trace under Flying with `switch_backfill` off and on.
/// Off is the PR-1/2 transition path and must stay byte-identical to the
/// loop reference (completion/rejection sets + switch counts); on reports
/// how much of the merge-window idle capacity backfill reclaimed.
fn switch_stall_compare(scenario: Scenario, cm: &CostModel, n: usize) -> SwitchRow {
    let trace = scenario.generate(4242, n);

    let off_cfg = SimConfig::default();
    let off = simulate(SimSystem::Flying, cm, &trace, &off_cfg);
    let reference = simulate_reference(SimSystem::Flying, cm, &trace, &off_cfg);
    let off_equivalent = match outcomes_equivalent(&off, &reference) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("switch {scenario}: backfill-off diverged from reference: {e}");
            false
        }
    };

    let on_cfg = SimConfig { switch_backfill: true, ..SimConfig::default() };
    let on = simulate(SimSystem::Flying, cm, &trace, &on_cfg);

    let reclaimed_frac = if off.switch_stall_s > 0.0 {
        1.0 - on.switch_stall_s / off.switch_stall_s
    } else {
        0.0
    };
    println!(
        "switch {:18} stall_off={:8.3} engine-s stall_on={:8.3} engine-s reclaimed={:5.1}% switches={}/{} off-equiv={}",
        scenario.label(),
        off.switch_stall_s,
        on.switch_stall_s,
        reclaimed_frac * 100.0,
        off.n_switches,
        on.n_switches,
        off_equivalent,
    );
    SwitchRow {
        scenario: scenario.label(),
        stall_off_s: off.switch_stall_s,
        stall_on_s: on.switch_stall_s,
        switches_off: off.n_switches,
        switches_on: on.n_switches,
        reclaimed_frac,
        off_equivalent,
    }
}

// ---------------------------------------------------------------------------
// Part 3b — KV migration: zero-recompute DP↔TP switches (ISSUE 4)
// ---------------------------------------------------------------------------

struct MigrateRow {
    scenario: &'static str,
    avoided_tokens: usize,
    ttft_p90_off: f64,
    ttft_p90_on: f64,
    switches_off: usize,
    switches_on: usize,
    off_equivalent: bool,
}

/// Run one switch-heavy scenario under Flying with `switch_migrate` off and
/// on.  Off is the PR-3 transition path and must stay byte-identical to the
/// loop reference (hard gate); on must carry live KV across the DP↔TP flips
/// (`recompute_tokens_avoided > 0`, hard gate) without hurting TTFT p90
/// (reported; dynamics-dependent, so advisory like the speedup target).
fn migrate_compare(scenario: Scenario, cm: &CostModel, n: usize) -> MigrateRow {
    let trace = scenario.generate(4242, n);

    let off_cfg = SimConfig { switch_migrate: false, ..SimConfig::default() };
    let off = simulate(SimSystem::Flying, cm, &trace, &off_cfg);
    let reference = simulate_reference(SimSystem::Flying, cm, &trace, &off_cfg);
    let off_equivalent = match outcomes_equivalent(&off, &reference) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("migrate {scenario}: migrate-off diverged from reference: {e}");
            false
        }
    };

    let on_cfg = SimConfig { switch_migrate: true, ..SimConfig::default() };
    let on = simulate(SimSystem::Flying, cm, &trace, &on_cfg);

    let row = MigrateRow {
        scenario: scenario.label(),
        avoided_tokens: on.recompute_tokens_avoided,
        ttft_p90_off: off.recorder.summary(None).p90_ttft,
        ttft_p90_on: on.recorder.summary(None).p90_ttft,
        switches_off: off.n_switches,
        switches_on: on.n_switches,
        off_equivalent,
    };
    println!(
        "migrate {:18} kv-carried={:9} tokens ttft_p90 off={:7.3}s on={:7.3}s switches={}/{} off-equiv={}",
        row.scenario,
        row.avoided_tokens,
        row.ttft_p90_off,
        row.ttft_p90_on,
        row.switches_off,
        row.switches_on,
        row.off_equivalent,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 3b' — prefix cache: cross-request shared-prefix KV reuse (ISSUE 10)
// ---------------------------------------------------------------------------

struct PrefixRow {
    scenario: &'static str,
    avoided_tokens: usize,
    ttft_p90_off: f64,
    ttft_p90_on: f64,
    off_equivalent: bool,
}

/// Run one scenario under Flying with `prefix_cache` off and on.  Off is
/// the pre-PR-10 path and must stay outcome-equivalent to the loop
/// reference on *every* scenario (hard gate — an unarmed cache must be
/// invisible); on reports how many prompt tokens admission adopted from
/// earlier requests' KV (`prefill_tokens_avoided`; hard-gated > 0 on
/// shared_prefix, where 80% of requests share one of six family
/// prefixes).  TTFT p90 off-vs-on is reported as advisory: adopted
/// prefixes skip prefill compute, but scheduling dynamics shift, so we
/// gate reuse, not latency.
fn prefix_compare(scenario: Scenario, cm: &CostModel, n: usize) -> PrefixRow {
    let trace = scenario.generate(4242, n);

    let off_cfg = SimConfig { prefix_cache: false, ..SimConfig::default() };
    let off = simulate(SimSystem::Flying, cm, &trace, &off_cfg);
    let reference = simulate_reference(SimSystem::Flying, cm, &trace, &off_cfg);
    let off_equivalent = match outcomes_equivalent(&off, &reference) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("prefix {scenario}: prefix-off diverged from reference: {e}");
            false
        }
    };

    let on_cfg = SimConfig { prefix_cache: true, ..SimConfig::default() };
    let on = simulate(SimSystem::Flying, cm, &trace, &on_cfg);

    let row = PrefixRow {
        scenario: scenario.label(),
        avoided_tokens: on.prefill_tokens_avoided,
        ttft_p90_off: off.recorder.summary(None).p90_ttft,
        ttft_p90_on: on.recorder.summary(None).p90_ttft,
        off_equivalent,
    };
    println!(
        "prefix {:18} adopted={:9} tokens ttft_p90 off={:7.3}s on={:7.3}s off-equiv={}",
        row.scenario,
        row.avoided_tokens,
        row.ttft_p90_off,
        row.ttft_p90_on,
        row.off_equivalent,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 3c — stall attribution: the breakdown must reconstruct the
// aggregate (ISSUE 7)
// ---------------------------------------------------------------------------

struct StallRow {
    scenario: &'static str,
    drain_wait_s: f64,
    settle_s: f64,
    migration_s: f64,
    backfill_recovered_s: f64,
    pipeline_overlap_s: f64,
    aggregate_s: f64,
    components_sum_ok: bool,
}

/// Run one switch-heavy scenario with backfill + migrate + overlap armed
/// (the richest transition path: every stall component can be nonzero) and
/// check the attribution identity
/// `switch_stall_s = drain_wait + settle + migration
///                   - backfill_recovered - pipeline_overlap`
/// to 1e-9 — the components are accumulated at the exact sites the
/// aggregate is touched, so any drift means a site was missed.
fn stall_attribution_probe(scenario: Scenario, cm: &CostModel, n: usize) -> StallRow {
    let trace = scenario.generate(4242, n);
    let cfg = SimConfig {
        switch_backfill: true,
        switch_migrate: true,
        overlap: true,
        ..SimConfig::default()
    };
    let o = simulate(SimSystem::Flying, cm, &trace, &cfg);
    let err = (o.stall.total() - o.switch_stall_s).abs();
    let ok = err < 1e-9;
    if !ok {
        eprintln!(
            "stall attribution {scenario}: components {} vs aggregate {} (err {err:e})",
            o.stall.total(),
            o.switch_stall_s
        );
    }
    let row = StallRow {
        scenario: scenario.label(),
        drain_wait_s: o.stall.drain_wait_s,
        settle_s: o.stall.settle_s,
        migration_s: o.stall.migration_s,
        backfill_recovered_s: o.stall.backfill_recovered_s,
        pipeline_overlap_s: o.stall.pipeline_overlap_s,
        aggregate_s: o.switch_stall_s,
        components_sum_ok: ok,
    };
    println!(
        "stall {:18} drain-wait={:8.3} settle={:8.3} migration={:8.3} backfill-recovered={:8.3} pipeline-overlap={:8.3} aggregate={:8.3} sum-ok={}",
        row.scenario,
        row.drain_wait_s,
        row.settle_s,
        row.migration_s,
        row.backfill_recovered_s,
        row.pipeline_overlap_s,
        row.aggregate_s,
        row.components_sum_ok,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 3e — step-pipeline overlap: migration hidden inside drain windows
// (ISSUE 9)
// ---------------------------------------------------------------------------

struct OverlapRow {
    scenario: &'static str,
    stall_off_s: f64,
    stall_on_s: f64,
    overlap_s: f64,
    migration_equal: bool,
    off_equivalent: bool,
}

/// Run one switch-heavy scenario under Flying with `overlap` off and on,
/// migrate armed on both sides so there are transfer windows to hide.  Two
/// gates: the plain overlap-off run must stay byte-identical to the loop
/// reference (hard gate — same discipline as backfill/migrate/watchdog
/// off), and overlap may only *re-attribute* migration time, never change
/// how much migration happened (`migration_s` equal within 1e-9, hard
/// gate).  The stall-reduction verdict is reported per scenario; the
/// aggregate PASS/MISS in main is advisory like the other dynamics-
/// dependent verdicts.
fn overlap_compare(scenario: Scenario, cm: &CostModel, n: usize) -> OverlapRow {
    let trace = scenario.generate(4242, n);

    // Hard gate: overlap-off on the plain path is the seed behavior.
    let base_cfg = SimConfig { overlap: false, ..SimConfig::default() };
    let base = simulate(SimSystem::Flying, cm, &trace, &base_cfg);
    let reference = simulate_reference(SimSystem::Flying, cm, &trace, &base_cfg);
    let off_equivalent = match outcomes_equivalent(&base, &reference) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("overlap {scenario}: overlap-off diverged from reference: {e}");
            false
        }
    };

    let off_cfg = SimConfig { switch_migrate: true, overlap: false, ..SimConfig::default() };
    let off = simulate(SimSystem::Flying, cm, &trace, &off_cfg);
    let on_cfg = SimConfig { switch_migrate: true, overlap: true, ..SimConfig::default() };
    let on = simulate(SimSystem::Flying, cm, &trace, &on_cfg);

    let row = OverlapRow {
        scenario: scenario.label(),
        stall_off_s: off.switch_stall_s,
        stall_on_s: on.switch_stall_s,
        overlap_s: on.stall.pipeline_overlap_s,
        migration_equal: (on.stall.migration_s - off.stall.migration_s).abs() < 1e-9,
        off_equivalent,
    };
    println!(
        "overlap {:18} stall_off={:8.3} engine-s stall_on={:8.3} engine-s hidden={:8.3} engine-s migration-equal={} off-equiv={}",
        row.scenario,
        row.stall_off_s,
        row.stall_on_s,
        row.overlap_s,
        row.migration_equal,
        row.off_equivalent,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 3d — scheduling-kernel dispatch overhead (ISSUE 5)
// ---------------------------------------------------------------------------

struct KernelRow {
    n_decisions: usize,
    kernel_ns: f64,
    reference_ns: f64,
    overhead_frac: f64,
    equivalent: bool,
}

/// Drive the same admission-decision stream once through the scheduling
/// kernel (`sched::Kernel` walk + `EngineIndex` + trace) and once through a
/// hand-inlined replica of the identical ring/requeue/dirty semantics.  The
/// decision sequences must be byte-identical (hard gate — the kernel
/// abstraction may cost nanoseconds, never decisions); the per-decision
/// overhead is reported for the perf trail.
fn kernel_dispatch_probe() -> KernelRow {
    use flying_serving::coordinator::policy::{ModeDecision, Policy, Snapshot};
    use flying_serving::sched::{Kernel, LeastLoaded, Placement, SchedAction, SchedEvent};
    use std::collections::VecDeque;

    let n_engines = 8usize;
    let cap_tokens = 200_000u64;
    let trace = Scenario::ElasticTiers.generate(4242, 4000);

    let snap = |backlog: usize, idle: usize| Snapshot {
        now: 0.0,
        queue_len: backlog,
        idle_engines: idle,
        n_engines,
        dp_capacity_tokens: cap_tokens as usize,
        max_tp: n_engines,
        kv_frac: 0.0,
    };

    // ---- kernel path ------------------------------------------------------
    let t0 = Instant::now();
    let kernel_actions: Vec<SchedAction> = {
        let mut kernel: Kernel<u32> = Kernel::new();
        kernel.enable_trace();
        for e in 0..n_engines {
            kernel.index.refresh_engine(e, true, true);
        }
        let mut policy = FlyingPolicy::default();
        let mut used = vec![0u64; n_engines];
        let mut load = vec![0usize; n_engines];
        let mut bound: VecDeque<(usize, u64)> = VecDeque::new();
        for (i, r) in trace.iter().enumerate() {
            kernel.on_event(SchedEvent::Arrival { h: i as u32, priority: r.priority });
            if i % 3 == 2 {
                if let Some((e, occ)) = bound.pop_front() {
                    used[e] -= occ;
                    load[e] -= 1;
                    if load[e] == 0 {
                        kernel.index.refresh_engine(e, true, true);
                    }
                    kernel.on_event(SchedEvent::StepComplete);
                }
            }
            if !kernel.should_walk() {
                continue;
            }
            let mut walk = kernel.begin_walk();
            while let Some((h, high)) = walk.next() {
                let q = &trace[h as usize];
                let total = (q.prompt_len + q.output_len) as u64;
                let s = snap(walk.backlog_now(), kernel.index.idle_count());
                let placement = match policy.decide_for(
                    q.id,
                    q.prompt_len,
                    q.output_len,
                    q.priority,
                    q.tp_demand,
                    &s,
                ) {
                    ModeDecision::Reject => Placement::Reject,
                    ModeDecision::Tp(p) => Placement::Tp { width: p.min(n_engines) as u32 },
                    ModeDecision::Dp => {
                        let mut ll = LeastLoaded::new();
                        let mut cands = kernel.index.dp_candidates();
                        while cands != 0 {
                            let e = cands.trailing_zeros() as usize;
                            cands &= cands - 1;
                            if used[e] + total <= cap_tokens {
                                ll.offer(e, load[e]);
                            }
                        }
                        match ll.pick() {
                            Some(e) => {
                                used[e] += total;
                                load[e] += 1;
                                kernel.index.refresh_engine(e, true, false);
                                bound.push_back((e, total));
                                Placement::Dp { unit: e as u32, backfill: false }
                            }
                            None => Placement::Defer,
                        }
                    }
                };
                walk.settle(h, high, q.id, placement);
            }
            kernel.end_walk(walk);
        }
        kernel.take_trace()
    };
    let kernel_s = t0.elapsed().as_secs_f64();

    // ---- hand-inlined reference (same semantics, no kernel) ---------------
    let t0 = Instant::now();
    let ref_actions: Vec<SchedAction> = {
        let mut high: VecDeque<u32> = VecDeque::new();
        let mut normal: VecDeque<u32> = VecDeque::new();
        let mut req_hi: VecDeque<u32> = VecDeque::new();
        let mut req_lo: VecDeque<u32> = VecDeque::new();
        let mut dirty = false;
        let mut actions = Vec::new();
        let mut policy = FlyingPolicy::default();
        let mut used = vec![0u64; n_engines];
        let mut load = vec![0usize; n_engines];
        let mut idle_mask = (1u64 << n_engines) - 1;
        let mut bound: VecDeque<(usize, u64)> = VecDeque::new();
        for (i, r) in trace.iter().enumerate() {
            match r.priority {
                Priority::High => high.push_back(i as u32),
                Priority::Normal => normal.push_back(i as u32),
            }
            dirty = true;
            if i % 3 == 2 {
                if let Some((e, occ)) = bound.pop_front() {
                    used[e] -= occ;
                    load[e] -= 1;
                    if load[e] == 0 {
                        idle_mask |= 1 << e;
                    }
                    dirty = true;
                }
            }
            if !dirty || (high.is_empty() && normal.is_empty()) {
                continue;
            }
            let backlog_total = high.len() + normal.len();
            let mut processed = 0usize;
            let mut progress = false;
            req_hi.clear();
            req_lo.clear();
            for phase_high in [true, false] {
                loop {
                    let popped =
                        if phase_high { high.pop_front() } else { normal.pop_front() };
                    let Some(h) = popped else { break };
                    processed += 1;
                    let backlog =
                        req_hi.len() + req_lo.len() + (backlog_total - processed);
                    let q = &trace[h as usize];
                    let total = (q.prompt_len + q.output_len) as u64;
                    let s = snap(backlog, idle_mask.count_ones() as usize);
                    let placement = match policy.decide_for(
                        q.id,
                        q.prompt_len,
                        q.output_len,
                        q.priority,
                        q.tp_demand,
                        &s,
                    ) {
                        ModeDecision::Reject => Placement::Reject,
                        ModeDecision::Tp(p) => {
                            Placement::Tp { width: p.min(n_engines) as u32 }
                        }
                        ModeDecision::Dp => {
                            let mut pick: Option<usize> = None;
                            for e in 0..n_engines {
                                if used[e] + total > cap_tokens {
                                    continue;
                                }
                                match pick {
                                    None => pick = Some(e),
                                    Some(p) if load[p] > load[e] => pick = Some(e),
                                    _ => {}
                                }
                            }
                            match pick {
                                Some(e) => {
                                    used[e] += total;
                                    load[e] += 1;
                                    idle_mask &= !(1 << e);
                                    bound.push_back((e, total));
                                    Placement::Dp { unit: e as u32, backfill: false }
                                }
                                None => Placement::Defer,
                            }
                        }
                    };
                    actions.push(SchedAction { rid: q.id, placement });
                    if matches!(placement, Placement::Defer) {
                        if phase_high {
                            req_hi.push_back(h);
                        } else {
                            req_lo.push_back(h);
                        }
                    } else {
                        progress = true;
                    }
                }
            }
            std::mem::swap(&mut high, &mut req_hi);
            std::mem::swap(&mut normal, &mut req_lo);
            if !progress {
                dirty = false;
            }
        }
        actions
    };
    let ref_s = t0.elapsed().as_secs_f64();

    let equivalent = kernel_actions == ref_actions;
    let n_decisions = kernel_actions.len().max(1);
    let row = KernelRow {
        n_decisions,
        kernel_ns: kernel_s * 1e9 / n_decisions as f64,
        reference_ns: ref_s * 1e9 / n_decisions as f64,
        overhead_frac: kernel_s / ref_s.max(1e-12) - 1.0,
        equivalent,
    };
    println!(
        "kernel dispatch: {} decisions  kernel={:.1} ns/decision  inlined={:.1} ns/decision  overhead={:+.1}%  decisions-equal={}",
        row.n_decisions,
        row.kernel_ns,
        row.reference_ns,
        row.overhead_frac * 100.0,
        row.equivalent,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 4 — KV lookup microbench: slab handle vs id side-index
// ---------------------------------------------------------------------------

struct LookupRow {
    n_requests: usize,
    handle_ns: f64,
    id_ns: f64,
    speedup: f64,
}

fn kv_lookup_microbench() -> LookupRow {
    let cfg = stub_cfg();
    let n_req = 512usize; // 1 block each out of the 1023-block pool
    let mut a = KvCacheAdaptor::new(cfg);
    let mut handles = Vec::with_capacity(n_req);
    for rid in 0..n_req as u64 {
        let h = a.register(rid, 1).expect("register");
        a.ensure_capacity_h(h, 8).expect("grow");
        handles.push(h);
    }
    let iters = 4000usize;

    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..iters {
        for &h in &handles {
            acc = acc.wrapping_add(a.slot_h(h, 3).expect("slot_h") as u64);
        }
    }
    let handle_ns = t0.elapsed().as_nanos() as f64 / (iters * n_req) as f64;
    std::hint::black_box(acc);

    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..iters {
        for rid in 0..n_req as u64 {
            acc = acc.wrapping_add(a.slot(rid, 3).expect("slot") as u64);
        }
    }
    let id_ns = t0.elapsed().as_nanos() as f64 / (iters * n_req) as f64;
    std::hint::black_box(acc);

    let row = LookupRow {
        n_requests: n_req,
        handle_ns,
        id_ns,
        speedup: id_ns / handle_ns,
    };
    println!(
        "kv lookup ({} live requests): handle={:.1} ns  id-index={:.1} ns  speedup={:.2}x",
        row.n_requests, row.handle_ns, row.id_ns, row.speedup,
    );
    row
}

// ---------------------------------------------------------------------------
// Part 5 — fault tolerance: watchdog differential + chaos + margin sweep
// (ISSUE 6)
// ---------------------------------------------------------------------------

/// Hard gate: with no faults injected, arming the lockstep watchdog must
/// not move a single token — outputs, rejections, and all-zero fault
/// counters match the blocking pre-watchdog path exactly.
fn watchdog_off_differential() -> anyhow::Result<bool> {
    let shapes = StaticShapes { b_dec: 16, c_prefill: 64 };
    let mk_trace = || -> Vec<ServeRequest> {
        (0..200u64)
            .map(|id| ServeRequest {
                id,
                prompt: vec![(id % 250) as i32; 12],
                max_new: 12,
                priority: if id % 16 == 0 { Priority::High } else { Priority::Normal },
                tp_demand: if id % 64 == 0 { Some(2) } else { None },
                arrival: 0.0,
            })
            .collect()
    };

    let mut c = Cluster::start_stub(stub_cfg(), shapes, 4)?;
    let off = c.run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::HardPreempt)?;
    c.shutdown();

    let mut c = Cluster::start_stub(stub_cfg(), shapes, 4)?;
    c.set_watchdog(WatchdogConfig { enabled: true, ..WatchdogConfig::default() });
    let on = c.run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::HardPreempt)?;
    let counters_clean = on.fault_stats == FaultStats::default() && c.failed_mask() == 0;
    c.shutdown();

    let equal = off.outputs == on.outputs && off.rejected == on.rejected && counters_clean;
    println!(
        "watchdog differential: outputs-equal={} rejected-equal={} counters-zero={}",
        off.outputs == on.outputs,
        off.rejected == on.rejected,
        counters_clean,
    );
    Ok(equal)
}

struct ChaosRow {
    seed: u64,
    wall_s: f64,
    conserved: bool,
    invariants_ok: bool,
    stats: FaultStats,
}

/// Chaos probe: the switch-churn scenario (the fault-injection stress
/// shape: frequent DP↔TP flips with live KV) under seeded randomized
/// per-engine fault plans.  Conservation and KV invariants are the hard
/// gates; the counters go to the JSON trail so fault-handling behavior has
/// a perf-history record.
fn chaos_probe(seed: u64) -> anyhow::Result<ChaosRow> {
    let shapes = StaticShapes { b_dec: 8, c_prefill: 32 };
    let plans: Vec<FaultPlan> = (0..4).map(|e| FaultPlan::randomized(seed, e)).collect();
    let raw = Scenario::SwitchChurn.generate(seed, 24);
    let span = raw.last().map(|r| r.arrival).unwrap_or(0.0).max(1e-9);
    let trace: Vec<ServeRequest> = raw
        .iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: vec![(r.id % 250) as i32; r.prompt_len.clamp(1, 24)],
            max_new: r.output_len.clamp(1, 6),
            priority: r.priority,
            tp_demand: r.tp_demand,
            arrival: r.arrival / span,
        })
        .collect();
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let mut c =
        Cluster::start_stub_with(stub_cfg(), shapes, 4, Duration::from_millis(400), &plans)?;
    c.set_watchdog(WatchdogConfig {
        enabled: true,
        reply_timeout: Duration::from_millis(150),
        retries: 2,
        backoff: Duration::from_millis(100),
        max_request_retries: 2,
        ..WatchdogConfig::default()
    });
    let t0 = Instant::now();
    let out = c.run_trace(trace, &mut FlyingPolicy::default(), Strategy::SoftPreempt)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let done: BTreeSet<u64> = out.outputs.keys().copied().collect();
    let rejected: BTreeSet<u64> = out.rejected.iter().copied().collect();
    let conserved = done.is_disjoint(&rejected)
        && done.union(&rejected).copied().collect::<BTreeSet<u64>>() == submitted;
    let invariants_ok = match c.check_invariants() {
        Ok(()) => true,
        Err(e) => {
            eprintln!("chaos seed={seed:#x}: KV invariants violated: {e:#}");
            false
        }
    };
    let stats = out.fault_stats;
    c.shutdown();
    println!(
        "chaos seed={seed:#x}: {} done / {} rejected in {}  faults={} timeouts={} ridden-out={} step-errors={} recovered={} aborted={}  conserved={} invariants={}",
        done.len(),
        rejected.len(),
        fmt_dur(wall_s),
        stats.engine_faults,
        stats.reply_timeouts,
        stats.stalls_ridden_out,
        stats.step_errors,
        stats.requests_recovered,
        stats.requests_aborted,
        conserved,
        invariants_ok,
    );
    Ok(ChaosRow { seed, wall_s, conserved, invariants_ok, stats })
}

struct MarginRow {
    margin: f64,
    binds: usize,
    completed: usize,
}

/// Sweep `SwitchConfig::backfill_margin` over a drain-heavy ladder: one
/// long DP resident opens a TP-2 drain, then elastic requests whose
/// predicted completions straddle the drain horizon are offered for
/// backfill.  A wider margin admits more of the ladder; the bind counts
/// justify the tuned default.  Every run must still complete every request
/// (hard gate — the margin re-times work, never loses it).
fn backfill_margin_sweep() -> anyhow::Result<Vec<MarginRow>> {
    let margins = [0.6, 0.8, 1.0, 1.2, 1.5];
    let shapes = StaticShapes { b_dec: 8, c_prefill: 32 };
    let mut rows = Vec::new();
    for &margin in &margins {
        let mut c = Cluster::start_stub(stub_cfg(), shapes, 2)?;
        c.set_switch_config(SwitchConfig {
            backfill: true,
            backfill_margin: margin,
            ..SwitchConfig::default()
        });
        let mut recorder = Recorder::new();
        let mut policy = FlyingPolicy::default();
        let mut n_submitted = 0usize;
        let mut submit = |c: &mut Cluster, rec: &mut Recorder, id: u64, max_new: usize, tp: Option<usize>| {
            c.submit(
                ServeRequest {
                    id,
                    prompt: vec![(id % 250) as i32; if tp.is_some() { 16 } else { 8 }],
                    max_new,
                    priority: Priority::Normal,
                    tp_demand: tp,
                    arrival: 0.0,
                },
                rec,
            );
            n_submitted += 1;
        };
        // Long resident: 1 prefill chunk + 27 decode steps of drain horizon.
        submit(&mut c, &mut recorder, 1, 28, None);
        for _ in 0..3 {
            c.step_once(&mut policy, Strategy::Sequential, &mut recorder)?;
        }
        // Explicit TP demand opens the sequential drain over both engines.
        submit(&mut c, &mut recorder, 2, 4, Some(2));
        c.step_once(&mut policy, Strategy::Sequential, &mut recorder)?;
        // The ladder: predicted completions from well inside the remaining
        // ~25-step horizon to well past it — which rungs bind is exactly
        // what the margin decides.
        for (i, max_new) in [2usize, 6, 10, 14, 18, 22].into_iter().enumerate() {
            submit(&mut c, &mut recorder, 10 + i as u64, max_new, None);
        }
        for _ in 0..20_000 {
            if !c.step_once(&mut policy, Strategy::Sequential, &mut recorder)? {
                break;
            }
        }
        let binds = c.backfill_binds();
        c.shutdown();
        let completed = (1..=2u64)
            .chain(10..16)
            .filter(|&id| recorder.get(id).map(|r| r.finished.is_some()).unwrap_or(false))
            .count();
        anyhow::ensure!(
            completed == n_submitted,
            "margin {margin}: {completed}/{n_submitted} requests completed — margin must re-time, not lose"
        );
        println!(
            "backfill margin {margin:>4}: {binds} binds admitted, {completed}/{n_submitted} completed"
        );
        rows.push(MarginRow { margin, binds, completed });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let n_requests = if quick { 20_000 } else { 100_000 };

    println!("== sched_hotpath: simulator event core vs reference (n={n_requests}) ==");
    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    let trace = generate(&WorkloadCfg::paper_full(4242, n_requests));
    let rows = vec![
        sim_compare(SimSystem::Flying, &cm, &trace),
        sim_compare(SimSystem::StaticTp(4), &cm, &trace),
        sim_compare(SimSystem::StaticDp, &cm, &trace),
    ];
    let min_speedup = rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min);
    let all_equiv = rows.iter().all(|r| r.equivalent);
    // Speedup is machine-dependent, so a miss is advisory; equivalence and
    // the allocation count below are deterministic and fail the run (CI
    // checks the exit code).
    println!(
        "simulator: min speedup {:.1}x across systems — target >= 5x: {}",
        min_speedup,
        if min_speedup >= 5.0 { "PASS" } else { "MISS" },
    );
    println!(
        "simulator: outcome equivalence (completions, rejections, switches): {}",
        if all_equiv { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: switch-heavy scenarios (drain-stall elimination) ==");
    let n_switchy = if quick { 700 } else { 2500 };
    let switch_rows = vec![
        switch_stall_compare(Scenario::PriorityStorm, &cm, n_switchy),
        switch_stall_compare(Scenario::PoissonBurst, &cm, n_switchy),
    ];
    let switch_off_equiv = switch_rows.iter().all(|r| r.off_equivalent);
    let stall_reduced = switch_rows
        .iter()
        .all(|r| r.stall_off_s > 0.0 && r.stall_on_s < r.stall_off_s);
    // Stall reduction is dynamics-dependent (divergent schedules), so the
    // verdict is advisory like the speedup target; the off-mode
    // differential equivalence below is the deterministic gate.
    println!(
        "switch backfill reduces stall on every scenario: {}",
        if stall_reduced { "PASS" } else { "MISS" },
    );
    println!(
        "switch backfill-off outcome equivalence vs reference: {}",
        if switch_off_equiv { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: KV migration (zero-recompute DP<->TP switches) ==");
    let migrate_rows = vec![
        migrate_compare(Scenario::LongContextWave, &cm, n_switchy),
        migrate_compare(Scenario::SwitchChurn, &cm, n_switchy),
    ];
    let migrate_off_equiv = migrate_rows.iter().all(|r| r.off_equivalent);
    let migrate_carried = migrate_rows.iter().all(|r| r.avoided_tokens > 0);
    // TTFT is dynamics-dependent (carried residents legitimately re-time the
    // schedule), so the no-regression verdict is advisory like the speedup
    // target; the off-mode differential and the carried-token floor are the
    // deterministic gates.
    let migrate_ttft_ok = migrate_rows
        .iter()
        .all(|r| r.ttft_p90_on <= r.ttft_p90_off * 1.02 + 1e-9);
    println!(
        "migrate carries live KV on every scenario (avoided > 0): {}",
        if migrate_carried { "PASS" } else { "FAIL" },
    );
    println!(
        "migrate TTFT p90 no worse than migrate-off: {}",
        if migrate_ttft_ok { "PASS" } else { "MISS" },
    );
    println!(
        "migrate-off outcome equivalence vs reference: {}",
        if migrate_off_equiv { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: stall attribution (components reconstruct aggregate) ==");
    let stall_rows = vec![
        stall_attribution_probe(Scenario::PriorityStorm, &cm, n_switchy),
        stall_attribution_probe(Scenario::SwitchChurn, &cm, n_switchy),
    ];
    let stall_sum_ok = stall_rows.iter().all(|r| r.components_sum_ok);
    println!(
        "stall components sum to switch_stall_s within 1e-9: {}",
        if stall_sum_ok { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: step-pipeline overlap (migration hidden in drain windows) ==");
    let overlap_rows = vec![
        overlap_compare(Scenario::SwitchChurn, &cm, n_switchy),
        overlap_compare(Scenario::PoissonBurst, &cm, n_switchy),
    ];
    let overlap_off_equiv = overlap_rows.iter().all(|r| r.off_equivalent);
    let overlap_migration_equal = overlap_rows.iter().all(|r| r.migration_equal);
    let overlap_reduced = overlap_rows
        .iter()
        .all(|r| r.overlap_s > 0.0 && r.stall_on_s < r.stall_off_s);
    // Stall reduction depends on the scenario producing carried migrations
    // (switch_churn always does; burst shapes vary), so the verdict is
    // advisory; the off-mode differential and the migration-conservation
    // check are the deterministic gates.
    println!(
        "overlap hides migration on every scenario: {}",
        if overlap_reduced { "PASS" } else { "MISS" },
    );
    println!(
        "overlap re-attributes (never changes) migration time: {}",
        if overlap_migration_equal { "PASS" } else { "FAIL" },
    );
    println!(
        "overlap-off outcome equivalence vs reference: {}",
        if overlap_off_equiv { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: prefix cache (cross-request shared-prefix reuse) ==");
    // Every scenario in the library: the unarmed cache must be invisible
    // everywhere, not just on shapes that happen to share prefixes.
    let prefix_rows: Vec<PrefixRow> =
        Scenario::ALL.iter().map(|&sc| prefix_compare(sc, &cm, n_switchy)).collect();
    let prefix_off_equiv = prefix_rows.iter().all(|r| r.off_equivalent);
    let prefix_adopted = prefix_rows
        .iter()
        .find(|r| r.scenario == Scenario::SharedPrefix.label())
        .map(|r| r.avoided_tokens > 0)
        .unwrap_or(false);
    // TTFT is dynamics-dependent (skipped prefill re-times the schedule),
    // so the no-regression verdict on the shared-prefix scenario is
    // advisory; the off-mode differential and the adopted-token floor are
    // the deterministic gates.
    let prefix_ttft_ok = prefix_rows
        .iter()
        .filter(|r| r.scenario == Scenario::SharedPrefix.label())
        .all(|r| r.ttft_p90_on <= r.ttft_p90_off * 1.02 + 1e-9);
    println!(
        "prefix cache adopts tokens on shared_prefix (avoided > 0): {}",
        if prefix_adopted { "PASS" } else { "FAIL" },
    );
    println!(
        "prefix TTFT p90 no worse than prefix-off on shared_prefix: {}",
        if prefix_ttft_ok { "PASS" } else { "MISS" },
    );
    println!(
        "prefix-off outcome equivalence vs reference on all scenarios: {}",
        if prefix_off_equiv { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: scheduling-kernel dispatch overhead ==");
    let kernel = kernel_dispatch_probe();
    // The kernel abstraction may cost nanoseconds, never decisions: the
    // decision-sequence equality is a deterministic hard gate; the
    // overhead figure is advisory (machine-dependent) like the speedup.
    println!(
        "kernel decisions identical to hand-inlined reference: {}",
        if kernel.equivalent { "PASS" } else { "FAIL" },
    );

    println!("\n== sched_hotpath: KV lookup (slab handle vs id index) ==");
    let lookup = kv_lookup_microbench();

    println!("\n== sched_hotpath: coordinator hot path (stub engines) ==");
    let alloc = coordinator_alloc_probe()?;
    println!(
        "zero-allocation steady state (median allocs/step == 0): {}",
        if alloc.median_allocs == 0 { "PASS" } else { "FAIL" },
    );
    let rps = coordinator_throughput_probe()?;

    println!("\n== sched_hotpath: fault tolerance (watchdog + chaos + margin sweep) ==");
    let watchdog_equal = watchdog_off_differential()?;
    println!(
        "watchdog-off byte-identical to baseline: {}",
        if watchdog_equal { "PASS" } else { "FAIL" },
    );
    let chaos_seed = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let chaos = chaos_probe(chaos_seed)?;
    println!(
        "chaos conservation + KV invariants: {}",
        if chaos.conserved && chaos.invariants_ok { "PASS" } else { "FAIL" },
    );
    let margin_rows = backfill_margin_sweep()?;
    let default_margin = SwitchConfig::default().backfill_margin;
    // Admission must widen with the margin (advisory: schedule divergence
    // between runs can blur single rungs, but the envelope is monotone).
    let margin_monotone = margin_rows.windows(2).all(|w| w[0].binds <= w[1].binds);
    println!(
        "backfill binds nondecreasing in margin (default {default_margin}): {}",
        if margin_monotone { "PASS" } else { "MISS" },
    );

    // ---- JSON artifact ----------------------------------------------------
    std::fs::create_dir_all("bench_out")?;
    let mut f = std::fs::File::create("bench_out/sched_hotpath.json")?;
    let sims: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"system\":\"{}\",\"new_s\":{:.6},\"ref_s\":{:.6},\"speedup\":{:.3},\"equivalent\":{}}}",
                r.system, r.new_s, r.ref_s, r.speedup, r.equivalent
            )
        })
        .collect();
    let switches: Vec<String> = switch_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"stall_off_engine_s\":{:.4},\"stall_on_engine_s\":{:.4},\"reclaimed_frac\":{:.4},\"switches_off\":{},\"switches_on\":{},\"off_equivalent\":{}}}",
                r.scenario,
                r.stall_off_s,
                r.stall_on_s,
                r.reclaimed_frac,
                r.switches_off,
                r.switches_on,
                r.off_equivalent,
            )
        })
        .collect();
    let migrates: Vec<String> = migrate_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"recompute_tokens_avoided\":{},\"ttft_p90_off_s\":{:.4},\"ttft_p90_on_s\":{:.4},\"switches_off\":{},\"switches_on\":{},\"off_equivalent\":{}}}",
                r.scenario,
                r.avoided_tokens,
                r.ttft_p90_off,
                r.ttft_p90_on,
                r.switches_off,
                r.switches_on,
                r.off_equivalent,
            )
        })
        .collect();
    let stalls_json: Vec<String> = stall_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"drain_wait_s\":{:.6},\"settle_s\":{:.6},\"migration_s\":{:.6},\"backfill_recovered_s\":{:.6},\"pipeline_overlap_s\":{:.6},\"aggregate_s\":{:.6},\"components_sum_ok\":{}}}",
                r.scenario,
                r.drain_wait_s,
                r.settle_s,
                r.migration_s,
                r.backfill_recovered_s,
                r.pipeline_overlap_s,
                r.aggregate_s,
                r.components_sum_ok,
            )
        })
        .collect();
    let overlaps_json: Vec<String> = overlap_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"stall_off_engine_s\":{:.4},\"stall_on_engine_s\":{:.4},\"pipeline_overlap_s\":{:.4},\"migration_equal\":{},\"off_equivalent\":{}}}",
                r.scenario,
                r.stall_off_s,
                r.stall_on_s,
                r.overlap_s,
                r.migration_equal,
                r.off_equivalent,
            )
        })
        .collect();
    let prefixes_json: Vec<String> = prefix_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"prefill_tokens_avoided\":{},\"ttft_p90_off_s\":{:.4},\"ttft_p90_on_s\":{:.4},\"off_equivalent\":{}}}",
                r.scenario,
                r.avoided_tokens,
                r.ttft_p90_off,
                r.ttft_p90_on,
                r.off_equivalent,
            )
        })
        .collect();
    let margins_json: Vec<String> = margin_rows
        .iter()
        .map(|r| {
            format!(
                "{{\"margin\":{:.2},\"backfill_binds\":{},\"completed\":{}}}",
                r.margin, r.binds, r.completed
            )
        })
        .collect();
    writeln!(
        f,
        "{{\"n_requests\":{},\"quick\":{},\"simulator\":[{}],\"switch_stall\":{{\"n_requests\":{},\"rows\":[{}],\"stall_reduced\":{}}},\"kv_migrate\":{{\"n_requests\":{},\"rows\":[{}],\"carried_everywhere\":{},\"ttft_ok\":{}}},\"stall_attribution\":{{\"n_requests\":{},\"rows\":[{}],\"components_sum_ok\":{}}},\"overlap\":{{\"n_requests\":{},\"rows\":[{}],\"stall_reduced\":{},\"migration_equal\":{},\"alloc_probe_armed\":true}},\"prefix_cache\":{{\"n_requests\":{},\"rows\":[{}],\"off_equivalent_all\":{},\"adopted_on_shared_prefix\":{},\"ttft_ok\":{},\"alloc_probe_armed\":true}},\"sched_kernel\":{{\"n_decisions\":{},\"kernel_ns\":{:.2},\"reference_ns\":{:.2},\"overhead_frac\":{:.4},\"equivalent\":{}}},\"kv_lookup\":{{\"n_live\":{},\"handle_ns\":{:.2},\"id_ns\":{:.2},\"speedup\":{:.3}}},\"coordinator\":{{\"steps\":{},\"median_allocs_per_step\":{},\"mean_allocs_per_step\":{:.3},\"steps_per_s\":{:.1},\"run_trace_rps\":{:.1}}},\"fault_tolerance\":{{\"watchdog_off_equivalent\":{},\"chaos\":{{\"seed\":{},\"wall_s\":{:.3},\"conserved\":{},\"invariants_ok\":{},\"engine_faults\":{},\"reply_timeouts\":{},\"stalls_ridden_out\":{},\"step_errors\":{},\"requests_recovered\":{},\"requests_aborted\":{}}},\"margin_sweep\":{{\"default_margin\":{:.2},\"monotone\":{},\"rows\":[{}]}}}}}}",
        n_requests,
        quick,
        sims.join(","),
        n_switchy,
        switches.join(","),
        stall_reduced,
        n_switchy,
        migrates.join(","),
        migrate_carried,
        migrate_ttft_ok,
        n_switchy,
        stalls_json.join(","),
        stall_sum_ok,
        n_switchy,
        overlaps_json.join(","),
        overlap_reduced,
        overlap_migration_equal,
        n_switchy,
        prefixes_json.join(","),
        prefix_off_equiv,
        prefix_adopted,
        prefix_ttft_ok,
        kernel.n_decisions,
        kernel.kernel_ns,
        kernel.reference_ns,
        kernel.overhead_frac,
        kernel.equivalent,
        lookup.n_requests,
        lookup.handle_ns,
        lookup.id_ns,
        lookup.speedup,
        alloc.steps,
        alloc.median_allocs,
        alloc.mean_allocs,
        alloc.steps_per_s,
        rps,
        watchdog_equal,
        chaos.seed,
        chaos.wall_s,
        chaos.conserved,
        chaos.invariants_ok,
        chaos.stats.engine_faults,
        chaos.stats.reply_timeouts,
        chaos.stats.stalls_ridden_out,
        chaos.stats.step_errors,
        chaos.stats.requests_recovered,
        chaos.stats.requests_aborted,
        default_margin,
        margin_monotone,
        margins_json.join(","),
    )?;
    println!("\nwrote bench_out/sched_hotpath.json");
    if !all_equiv {
        anyhow::bail!("event core diverged from the reference simulator");
    }
    if !kernel.equivalent {
        anyhow::bail!("scheduling-kernel decisions diverged from the hand-inlined reference");
    }
    if !switch_off_equiv {
        anyhow::bail!("switch-heavy backfill-off run diverged from the reference simulator");
    }
    if !migrate_off_equiv {
        anyhow::bail!("migrate-off run diverged from the reference simulator");
    }
    if !migrate_carried {
        anyhow::bail!("KV migration carried no tokens on a switch-heavy scenario");
    }
    if !stall_sum_ok {
        anyhow::bail!("stall components do not reconstruct switch_stall_s within 1e-9");
    }
    if !overlap_off_equiv {
        anyhow::bail!("overlap-off run diverged from the reference simulator");
    }
    if !overlap_migration_equal {
        anyhow::bail!("overlap changed migration_s instead of re-attributing it");
    }
    if !prefix_off_equiv {
        anyhow::bail!("prefix-cache-off run diverged from the reference simulator");
    }
    if !prefix_adopted {
        anyhow::bail!("prefix cache adopted no tokens on shared_prefix");
    }
    if alloc.median_allocs != 0 {
        anyhow::bail!(
            "coordinator steady state allocates (median {} allocs/step, expected 0)",
            alloc.median_allocs
        );
    }
    if !watchdog_equal {
        anyhow::bail!("fault-free watchdog run diverged from the blocking baseline");
    }
    if !chaos.conserved {
        anyhow::bail!("chaos probe lost or invented requests (seed {:#x})", chaos.seed);
    }
    if !chaos.invariants_ok {
        anyhow::bail!("chaos probe violated KV invariants (seed {:#x})", chaos.seed);
    }
    Ok(())
}
