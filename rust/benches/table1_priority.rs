//! Table 1 — Llama-70B under a mixed-priority workload (sim 8×H200).
//!
//! Paper: arrival 3–5 req/s, interleaved high-priority requests; reports
//! mean TPOT / TTFT for the priority class and for all requests, plus peak
//! throughput, under static TP, static DP, and FLYING SERVING (hard
//! preempt).  Expected shape: FLYING within ~1.1-1.2x of static TP for the
//! priority class, ~15x better mean TTFT (all) than TP under load, and
//! ~96% of DP peak throughput.

use flying_serving::sim::{simulate, CostModel, HwSpec, PaperModel, SimConfig, SimSystem};
use flying_serving::util::bench::Table;
use flying_serving::workload::{generate, Priority, WorkloadCfg};

fn main() -> anyhow::Result<()> {
    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    // Paper: arrival modulated between 3-5 req/s with interleaved
    // high-priority requests.  (On this cost model 3-5 r/s does not
    // saturate full-node TP, so the paper's TP-collapse row reproduces in
    // the fig8 saturation regime instead — see EXPERIMENTS.md.)
    let mut wl = WorkloadCfg::paper_full(77, 1200);
    wl.low_rate = (3.0, 5.0);
    wl.high_rate = (3.0, 5.0);
    wl.priority_frac = 0.10;
    let trace = generate(&wl);

    let mut t = Table::new(
        "Table 1 — Llama-70B under mixed-priority workload (sim 8xH200)",
        &["metric", "static TP", "static DP", "flying (ours)"],
    );

    let mut cols: Vec<(String, Vec<f64>)> = Vec::new();
    for sys in [SimSystem::StaticTp(8), SimSystem::StaticDp, SimSystem::Flying] {
        let o = simulate(sys, &cm, &trace, &SimConfig::default());
        let pri = o.recorder.summary(Some(Priority::High));
        let all = o.recorder.summary(None);
        cols.push((
            sys.label().to_string(),
            vec![
                pri.mean_tpot * 1e3,
                all.mean_tpot * 1e3,
                pri.mean_ttft * 1e3,
                all.mean_ttft * 1e3,
                all.peak_throughput,
            ],
        ));
    }
    let rows = [
        "Mean TPOT (priority) (ms)",
        "Mean TPOT (all) (ms)",
        "Mean TTFT (priority) (ms)",
        "Mean TTFT (all) (ms)",
        "Peak Throughput (tokens/s)",
    ];
    for (i, name) in rows.iter().enumerate() {
        t.row(&[
            name.to_string(),
            format!("{:.0}", cols[0].1[i]),
            format!("{:.0}", cols[1].1[i]),
            format!("{:.0}", cols[2].1[i]),
        ]);
    }
    t.print();
    t.write_csv("table1_priority")?;

    // Paper's derived claims.
    let fly_pri_ttft = cols[2].1[2];
    let dp_pri_ttft = cols[1].1[2];
    let tp_all_ttft = cols[0].1[3];
    let fly_all_ttft = cols[2].1[3];
    let fly_peak = cols[2].1[4];
    let dp_peak = cols[1].1[4];
    println!("\nderived (paper's comparison points):");
    println!(
        "  priority TTFT: flying {:.2}x better than static DP (paper 2.24x)",
        dp_pri_ttft / fly_pri_ttft
    );
    println!(
        "  mean TTFT (all): flying {:.1}x lower than static TP (paper 15.0x)",
        tp_all_ttft / fly_all_ttft
    );
    println!(
        "  peak throughput: flying retains {:.0}% of DP (paper 96%)",
        100.0 * fly_peak / dp_peak
    );
    Ok(())
}
