//! Adaptive-control bench: controller ablation across the scenario library.
//!
//! For every scenario in `workload::Scenario::ALL` (diurnal, poisson_burst,
//! long_context_wave, priority_storm, mixed_shift) this drives the same
//! trace through `sim::simulate_adaptive` under four controllers:
//!
//!   * `static-dp`  — `StaticController::dp()`: elastic traffic pinned DP.
//!   * `static-tp`  — `StaticController::tp(n_units)`: pinned full-width TP.
//!   * `threshold`  — reactive queue/burst bands with a hysteresis dead-band.
//!   * `costmodel`  — layout scoring against `sim::costmodel::CostModel`.
//!
//! All four share the per-request correctness constraints (explicit TP
//! demand, memory-driven binding, priority binding), so the comparison
//! isolates the *elastic* steering — the decision loop the paper's adaptive
//! wins come from.  Reported per run: goodput (SLO-attained requests/s,
//! with a length-proportional TTFT SLO so long-context requests earn
//! prefill budgets), TTFT p90, reject rate, engine switch count, and the
//! control plane's plan changes.
//!
//! Deterministic checks (non-zero exit on failure):
//!   * no-thrash: plan changes ≤ makespan / cooldown + 1 for every run —
//!     the cooldown bound the runtime guarantees by construction.
//! Advisory verdict (printed + JSON, machine-independent but calibration-
//! sensitive): `costmodel` beats BOTH static baselines on goodput or TTFT
//! p90 on ≥ 3 of the 5 scenarios.
//!
//! Usage:  cargo bench --bench ctrl_adapt [-- --quick]
//!   --quick : 1200 requests/scenario (CI smoke; full mode uses 4000).
//!
//! Writes bench_out/ctrl_adapt.json for the CI artifact trail.

use std::io::Write;
use std::time::Instant;

use flying_serving::control::{
    ControlConfig, ControlRuntime, Controller, CostModelController, StaticController,
    ThresholdController,
};
use flying_serving::metrics::ReqRecord;
use flying_serving::sim::{simulate_adaptive, CostModel, HwSpec, PaperModel, SimConfig};
use flying_serving::util::bench::fmt_dur;
use flying_serving::workload::Scenario;

/// TTFT SLO for one request: a fixed queueing/interactivity budget plus a
/// multiple of the request's ideal full-node prefill time, so 600K-token
/// prompts are graded against an achievable target rather than auto-failing.
fn slo_for(cm: &CostModel, r: &ReqRecord) -> f64 {
    5.0 + 3.0 * cm.prefill_s(r.prompt_len, cm.hw.n_gpus)
}

struct Row {
    scenario: &'static str,
    controller: &'static str,
    n: usize,
    finished: usize,
    rejected: usize,
    goodput_rps: f64,
    attain_frac: f64,
    ttft_p90: f64,
    n_switches: usize,
    plan_changes: usize,
    ticks: usize,
    wall_s: f64,
}

fn run_one(
    cm: &CostModel,
    scenario: Scenario,
    trace: &[flying_serving::workload::Request],
    controller: Box<dyn Controller>,
) -> Row {
    let ctrl_cfg = ControlConfig {
        long_threshold: cm.kv_capacity_tokens(cm.model.min_gpus),
        ..ControlConfig::default()
    };
    let cooldown_s = ctrl_cfg.cooldown_s;
    let mut rt = ControlRuntime::new(controller, ctrl_cfg);
    let name = rt.controller_name();

    let t0 = Instant::now();
    let o = simulate_adaptive(cm, trace, &SimConfig::default(), &mut rt);
    let wall_s = t0.elapsed().as_secs_f64();

    let s = o.recorder.summary(None);
    let attained = o.recorder.slo_attained(|r| slo_for(cm, r));
    let makespan = o.recorder.makespan().max(1e-9);

    // The no-thrash guarantee is structural (runtime cooldown); verify it
    // held on the real event stream.
    let bound = (makespan / cooldown_s).ceil() as usize + 1;
    assert!(
        rt.plan_changes() <= bound,
        "{scenario}/{name}: plan thrash — {} changes > bound {bound}",
        rt.plan_changes()
    );

    let row = Row {
        scenario: scenario.label(),
        controller: name,
        n: trace.len(),
        finished: s.finished,
        rejected: o.rejected.len(),
        goodput_rps: attained as f64 / makespan,
        attain_frac: attained as f64 / trace.len() as f64,
        ttft_p90: s.p90_ttft,
        n_switches: o.n_switches,
        plan_changes: rt.plan_changes(),
        ticks: rt.ticks(),
        wall_s,
    };
    println!(
        "  {:16} {:14} goodput={:6.2} req/s attain={:5.1}% ttft_p90={:7.2}s rejected={:4} switches={:5} plans={:3} ({})",
        row.scenario,
        row.controller,
        row.goodput_rps,
        row.attain_frac * 100.0,
        row.ttft_p90,
        row.rejected,
        row.n_switches,
        row.plan_changes,
        fmt_dur(row.wall_s),
    );
    row
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let n_requests = if quick { 1200 } else { 4000 };
    let seed = 4242u64;

    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    let n_units = cm.hw.n_gpus / cm.model.min_gpus;

    println!(
        "== ctrl_adapt: controllers x scenarios ({} · {n_requests} reqs/scenario, {n_units} units) ==",
        cm.model.name
    );

    let mut rows: Vec<Row> = Vec::new();
    let mut cm_wins = 0usize;
    for scenario in Scenario::ALL {
        let trace = scenario.generate(seed, n_requests);
        println!("-- {scenario} --");
        let dp = run_one(&cm, scenario, &trace, Box::new(StaticController::dp()));
        let tp = run_one(
            &cm,
            scenario,
            &trace,
            Box::new(StaticController::tp(n_units)),
        );
        let th = run_one(
            &cm,
            scenario,
            &trace,
            Box::new(ThresholdController::default()),
        );
        let cmc = run_one(
            &cm,
            scenario,
            &trace,
            Box::new(CostModelController::new(cm.clone())),
        );

        // Win = strictly better than BOTH static baselines on goodput, or
        // on TTFT p90 (NaN percentiles never count as a win).
        let wins_goodput = cmc.goodput_rps > dp.goodput_rps && cmc.goodput_rps > tp.goodput_rps;
        let wins_ttft = cmc.ttft_p90.is_finite()
            && cmc.ttft_p90 < dp.ttft_p90
            && cmc.ttft_p90 < tp.ttft_p90;
        let won = wins_goodput || wins_ttft;
        cm_wins += won as usize;
        println!(
            "  -> costmodel vs static: goodput {} / ttft_p90 {}  [{}]",
            if wins_goodput { "WIN" } else { "loss" },
            if wins_ttft { "WIN" } else { "loss" },
            if won { "WIN" } else { "LOSS" },
        );
        rows.extend([dp, tp, th, cmc]);
    }

    let target = 3usize;
    println!(
        "\ncostmodel beats both static baselines on {cm_wins}/{} scenarios — target >= {target}: {}",
        Scenario::ALL.len(),
        if cm_wins >= target { "PASS" } else { "MISS" },
    );

    // ---- JSON artifact ----------------------------------------------------
    std::fs::create_dir_all("bench_out")?;
    let mut f = std::fs::File::create("bench_out/ctrl_adapt.json")?;
    let rows_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"controller\":\"{}\",\"n\":{},\"finished\":{},\"rejected\":{},\"goodput_rps\":{:.4},\"attain_frac\":{:.4},\"ttft_p90_s\":{:.4},\"n_switches\":{},\"plan_changes\":{},\"ticks\":{},\"wall_s\":{:.4}}}",
                r.scenario,
                r.controller,
                r.n,
                r.finished,
                r.rejected,
                r.goodput_rps,
                r.attain_frac,
                if r.ttft_p90.is_finite() { r.ttft_p90 } else { -1.0 },
                r.n_switches,
                r.plan_changes,
                r.ticks,
                r.wall_s,
            )
        })
        .collect();
    writeln!(
        f,
        "{{\"n_requests_per_scenario\":{},\"quick\":{},\"model\":\"{}\",\"n_units\":{},\"costmodel_wins\":{},\"win_target\":{},\"rows\":[{}]}}",
        n_requests,
        quick,
        cm.model.name,
        n_units,
        cm_wins,
        target,
        rows_json.join(","),
    )?;
    println!("wrote bench_out/ctrl_adapt.json");
    Ok(())
}
