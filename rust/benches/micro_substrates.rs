//! Micro-benchmarks of the switching substrate — the mechanisms Table 2's
//! "15 ms live" column is made of, measured on the real path:
//!
//!  * KV Cache Adaptor ops (allocate / slot / table / pause / relayout) —
//!    must be O(1)-ish metadata, far below the per-step budget;
//!  * Communicator Pool: eager-init cost, O(1) group fetch, all-reduce
//!    latency across threads, and the eager-vs-lazy ablation;
//!  * real engine step latencies (DP decode, DP prefill chunk) and the
//!    SetMode switch RPC.

use std::sync::Arc;
use std::time::Duration;

use flying_serving::comm::CommunicatorPool;
use flying_serving::engine::EngineCmd;
use flying_serving::kv::KvCacheAdaptor;
use flying_serving::model::ModelCfg;
use flying_serving::runtime::Manifest;
use flying_serving::util::bench::{bench, Table};

fn kv_cfg() -> ModelCfg {
    ModelCfg {
        name: "bench".into(),
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 32,
        ffn_hidden: 512,
        n_experts: 0,
        top_k: 0,
        n_blocks: 128,
        block_base: 8,
        max_ctx: 4096,
        vocab: 258,
        pool_elems: 128 * 8 * 4 * 32,
    }
}

fn main() -> anyhow::Result<()> {
    println!("== KV Cache Adaptor (metadata plane) ==");
    let cfg = kv_cfg();
    bench("kv: register+alloc 512 tokens+release", 100, 2000, || {
        let mut a = KvCacheAdaptor::new(cfg.clone());
        a.register(1, 1).unwrap();
        a.ensure_capacity(1, 512).unwrap();
        a.release(1).unwrap();
    });
    let mut a = KvCacheAdaptor::new(cfg.clone());
    a.register(1, 1).unwrap();
    a.ensure_capacity(1, 512).unwrap();
    bench("kv: slot lookup", 100, 100_000, || {
        std::hint::black_box(a.slot(1, 317).unwrap());
    });
    bench("kv: table row (padded)", 100, 20_000, || {
        std::hint::black_box(a.table_row(1).unwrap());
    });
    bench("kv: pause+resume (hard preempt)", 100, 50_000, || {
        a.pause(1).unwrap();
        a.resume(1).unwrap();
    });
    bench("kv: mode-switch metadata cost", 100, 100_000, || {
        std::hint::black_box(a.switch_mode_metadata_cost());
    });

    println!("\n== Communicator Pool (data plane) ==");
    let to = Duration::from_secs(5);
    bench("comm: eager pool init (8 engines, P={1,2,4,8})", 10, 2000, || {
        std::hint::black_box(CommunicatorPool::new(8, &[1, 2, 4, 8], to));
    });
    let pool = CommunicatorPool::new(8, &[1, 2, 4, 8], to);
    bench("comm: O(1) group fetch (the paper's activation)", 100, 100_000, || {
        std::hint::black_box(pool.group_of(3, 4).unwrap());
    });
    // Eager-vs-lazy ablation: what a lazy design would pay on the critical
    // path per switch (group construction) vs the pool fetch.
    let lazy = bench("comm ablation: lazy group construction", 100, 2000, || {
        std::hint::black_box(CommunicatorPool::new(8, &[4], to));
    });
    let eager = bench("comm ablation: eager pool fetch", 100, 100_000, || {
        std::hint::black_box(pool.group_of(3, 4).unwrap());
    });
    println!(
        "  -> eager activation is {:.0}x cheaper on the switch path",
        lazy.mean_s / eager.mean_s.max(1e-12)
    );

    let g = pool.get(&[0, 1]).unwrap();
    bench("comm: 2-way all-reduce 256 f32 (threads)", 50, 2000, || {
        let g2 = g.clone();
        let h = std::thread::spawn(move || {
            let mut d = vec![1.0f32; 256];
            g2.all_reduce_sum(1, &mut d).unwrap();
        });
        let mut d = vec![2.0f32; 256];
        g.all_reduce_sum(0, &mut d).unwrap();
        h.join().unwrap();
    });

    println!("\n== Real engine step path (PJRT, llama-tiny) ==");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(skipped: run `make artifacts`)");
        return Ok(());
    }
    let manifest = Arc::new(Manifest::load(dir)?);
    let mm = manifest.model("llama-tiny")?;
    let ws = Arc::new(mm.load_weights()?);
    let comm = Arc::new(CommunicatorPool::new(2, &[1, 2], to));
    let eng = flying_serving::engine::EngineHandle::spawn(
        0,
        manifest.clone(),
        "llama-tiny".into(),
        ws,
        comm,
    )?;

    // SetMode: the entire engine-side cost of a DP<->TP switch.
    let mut flip = 1usize;
    let sw = bench("engine: SetMode switch RPC roundtrip", 20, 2000, || {
        flip = 3 - flip; // 1 <-> 2
        eng.call(EngineCmd::SetMode { p: flip }).unwrap();
    });

    // One fused DP decode step, batch of 4.
    let mut adapt = KvCacheAdaptor::new(mm.cfg.clone());
    for rid in 1..=4u64 {
        adapt.register(rid, 1).unwrap();
        adapt.ensure_capacity(rid, 128).unwrap();
    }
    eng.call(EngineCmd::SetMode { p: 1 }).unwrap();
    // Seed one token per request then time steady-state decode steps.
    let mk_batch = |adapt: &KvCacheAdaptor, pos: usize| {
        (1..=4u64)
            .map(|rid| flying_serving::engine::DecodeSlot {
                rid,
                token: (rid as i32) % 250,
                pos,
                slot_id: adapt.slot(rid, pos).unwrap(),
                table_row: adapt.table_row(rid).unwrap(),
            })
            .collect::<Vec<_>>()
    };
    let mut pos = 0usize;
    let step = bench("engine: fused DP decode step (batch 4)", 5, 60, || {
        let batch = Arc::new(mk_batch(&adapt, pos));
        eng.call(EngineCmd::DpDecode { batch }).unwrap();
        pos += 1;
    });
    println!(
        "  -> switch/step ratio: a mode switch costs {:.2}% of one decode step",
        100.0 * sw.mean_s / step.mean_s
    );

    let mut t = Table::new(
        "Switching-substrate summary",
        &["operation", "mean latency (µs)"],
    );
    t.row(&["SetMode switch RPC".into(), format!("{:.1}", sw.mean_s * 1e6)]);
    t.row(&["decode step (batch 4)".into(), format!("{:.1}", step.mean_s * 1e6)]);
    t.write_csv("micro_substrates")?;
    t.print();

    drop(eng);
    Ok(())
}
