//! Figure 10 — ultra long-context stress test at each model's maximum
//! supported context (8K Llama-70B, 128K GPT-OSS-120B, 1M Nemotron-8B).
//!
//! Reports peak prompt throughput, TTFT, and ILT for static DP, static TP,
//! and FLYING SERVING on the simulated node.  Expected shape: FLYING
//! sustains DP-level prompt throughput (1.29-1.38x over static TP), with
//! TP-like TTFT (2.8-3x better than DP) and TP-like ILT (1.85-1.88x better
//! than DP).

use flying_serving::sim::{simulate, CostModel, HwSpec, PaperModel, SimConfig, SimSystem};
use flying_serving::util::bench::Table;
use flying_serving::workload::{Priority, Request};

fn long_trace(n: usize, ctx: usize, out: usize, gap: f64) -> Vec<Request> {
    (0..n as u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * gap,
            prompt_len: ctx,
            output_len: out,
            priority: Priority::Normal,
            tp_demand: None,
            prefix_family: None,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let cases = [
        (PaperModel::llama70b(), 8_192usize),
        (PaperModel::gptoss120b(), 131_072),
        (PaperModel::nemotron8b(), 1_000_000),
    ];

    let mut t = Table::new(
        "Fig 10 — long-context stress (sim 8xH200)",
        &["model", "ctx", "system", "peak prompt tok/s", "TTFT (s)", "ILT (ms)"],
    );
    let mut ratios = Table::new(
        "Fig 10 ratios (paper: prompt thpt fly/tp 1.29-1.38x; TTFT dp/fly 2.8-3x; ILT dp/fly 1.85-1.88x)",
        &["model", "prompt fly/tp", "TTFT dp/fly", "ILT dp/fly"],
    );

    for (model, ctx) in cases {
        let name = model.name;
        let cm = CostModel::new(HwSpec::default(), model);
        // Enough concurrent long requests to saturate; arrival gap scales
        // with context so every system reaches steady state.
        let n = 24;
        let gap = cm.prefill_s(ctx, cm.hw.n_gpus) * 1.05;
        let trace = long_trace(n, ctx, 64, gap);

        let mut metrics = std::collections::BTreeMap::new();
        for sys in [SimSystem::StaticDp, SimSystem::StaticTp(8), SimSystem::Flying] {
            let o = simulate(sys, &cm, &trace, &SimConfig::default());
            let s = o.recorder.summary(None);
            if o.rejected.len() >= n {
                // Every request exceeded this configuration's KV capacity —
                // the OOM failure that motivates Use Case 3.
                t.row(&[
                    name.to_string(),
                    format!("{}", ctx),
                    sys.label().to_string(),
                    "OOM".into(),
                    "OOM".into(),
                    "OOM".into(),
                ]);
                metrics.insert(sys.label(), (f64::NAN, f64::NAN, f64::NAN, o.rejected.len()));
                continue;
            }
            // Peak prompt throughput: prompt tokens / prefill span, counting
            // only served (non-rejected) requests.
            let served = s.finished.max(1);
            let prompt_tokens = served as f64 * ctx as f64;
            let span: f64 = {
                let mut lo = f64::INFINITY;
                let mut hi: f64 = 0.0;
                for (_, r) in o.recorder.records() {
                    if let (Some(first), Some(q)) = (r.token_times.first(), r.first_sched) {
                        lo = lo.min(q);
                        hi = hi.max(*first);
                    }
                }
                (hi - lo).max(1e-9)
            };
            let prompt_thpt = prompt_tokens / span;
            t.row(&[
                name.to_string(),
                format!("{}", ctx),
                sys.label().to_string(),
                format!("{:.0}", prompt_thpt),
                format!("{:.2}", s.mean_ttft),
                format!("{:.1}", s.mean_ilt * 1e3),
            ]);
            metrics.insert(sys.label(), (prompt_thpt, s.mean_ttft, s.mean_ilt, o.rejected.len()));
        }
        let g = |k: &str| metrics[k];
        ratios.row(&[
            name.to_string(),
            format!("{:.2}x", g("flying").0 / g("static-tp").0),
            format!("{:.2}x", g("static-dp").1 / g("flying").1),
            format!("{:.2}x", g("static-dp").2 / g("flying").2),
        ]);
        if g("static-dp").3 > 0 {
            println!(
                "note: {name} static-dp rejected {} over-capacity requests at ctx={ctx}",
                g("static-dp").3
            );
        }
    }

    t.print();
    t.write_csv("fig10_long_context")?;
    ratios.print();
    ratios.write_csv("fig10_ratios")?;
    Ok(())
}
