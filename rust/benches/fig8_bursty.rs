//! Figure 8 — end-to-end performance under bursty traffic.
//!
//! Columns: Llama-3-70B / GPT-OSS-120B / Nemotron-8B; rows: in-flight
//! concurrency, P90 TTFT, queue time over the trace, for static DP,
//! static TP, Shift-Parallelism, and FLYING SERVING on the simulated
//! 8×H200 node (same policy code as the real path; see DESIGN.md
//! §Substitutions).  Emits the per-system time series as CSVs in
//! bench_out/ plus the paper's summary claims (burst vs flat TTFT, the
//! headline speedups).

use flying_serving::sim::{simulate, CostModel, HwSpec, PaperModel, SimConfig, SimSystem};
use flying_serving::util::bench::{write_series_csv, Table};
use flying_serving::workload::{generate, WorkloadCfg};

const SYSTEMS: [SimSystem; 4] = [
    SimSystem::StaticDp,
    SimSystem::StaticTp(8),
    SimSystem::Shift,
    SimSystem::Flying,
];

fn main() -> anyhow::Result<()> {
    let n_requests = 800; // scaled from the paper's 4000 (same burst count)
    let models = [
        PaperModel::llama70b(),
        PaperModel::gptoss120b(),
        PaperModel::nemotron8b(),
    ];

    let mut summary = Table::new(
        "Fig 8 summary — bursty trace (sim 8xH200)",
        &["model", "system", "TTFT@burst (s)", "TTFT@flat (ms)", "p90 TTFT (s)", "p90 queue (s)"],
    );
    let mut headline = Table::new(
        "Headline speedups (FLYING vs static TP, p90 TTFT)",
        &["model", "speedup"],
    );

    for model in models {
        let name = model.name;
        let cm = CostModel::new(HwSpec::default(), model);
        let mut wl = WorkloadCfg::paper_full(4242, n_requests);
        // Per-model rate translation: the paper's 2-5 / 10-30 req/s sit at
        // fixed fractions of Llama-70B's TP-saturation point on their
        // testbed; apply the same fractions to each model's saturation on
        // this cost model (DESIGN.md §Substitutions).
        let sat = cm.tp_saturation_rps(2064, 288);
        wl.low_rate = (0.12 * sat, 0.30 * sat);
        wl.high_rate = (0.60 * sat, 1.20 * sat);
        let trace = generate(&wl);
        let phase_secs = wl.phase_secs;

        let mut tp_p90 = f64::NAN;
        let mut fly_p90 = f64::NAN;
        let mut conc_cols = Vec::new();
        let mut ttft_cols = Vec::new();
        let mut queue_cols = Vec::new();

        for sys in SYSTEMS {
            // Shift-Parallelism does not support GPT-OSS (paper footnote 5).
            if sys == SimSystem::Shift && name.contains("GPT-OSS") {
                continue;
            }
            let o = simulate(sys, &cm, &trace, &SimConfig::default());
            let s = o.recorder.summary(None);

            // Phase-resolved TTFT: bucket requests by arrival phase.
            let mut burst = Vec::new();
            let mut flat = Vec::new();
            for (_, r) in o.recorder.records() {
                if let Some(t) = r.ttft() {
                    if ((r.arrival / phase_secs) as usize) % 2 == 1 {
                        burst.push(t);
                    } else {
                        flat.push(t);
                    }
                }
            }
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
            summary.row(&[
                name.to_string(),
                sys.label().to_string(),
                format!("{:.2}", mean(&burst)),
                format!("{:.0}", mean(&flat) * 1e3),
                format!("{:.2}", s.p90_ttft),
                format!("{:.2}", s.p90_queue),
            ]);
            if matches!(sys, SimSystem::StaticTp(_)) {
                tp_p90 = s.p90_ttft;
            }
            if sys == SimSystem::Flying {
                fly_p90 = s.p90_ttft;
            }

            conc_cols.push((sys.label(), o.recorder.concurrency_series(2.0)));
            ttft_cols.push((sys.label(), o.recorder.ttft_p90_series(2.0)));
            queue_cols.push((sys.label(), o.recorder.queue_series(2.0)));
        }

        headline.row(&[name.to_string(), format!("{:.2}x", tp_p90 / fly_p90)]);

        let slug = name.to_lowercase().replace(['-', ' ', '.'], "_");
        fn refs<'a>(cols: &'a [(&'a str, Vec<(f64, f64)>)]) -> Vec<(&'a str, &'a [(f64, f64)])> {
            cols.iter().map(|(n, s)| (*n, s.as_slice())).collect()
        }
        write_series_csv(&format!("fig8_{slug}_concurrency"), &refs(&conc_cols))?;
        write_series_csv(&format!("fig8_{slug}_ttft_p90"), &refs(&ttft_cols))?;
        write_series_csv(&format!("fig8_{slug}_queue"), &refs(&queue_cols))?;
    }

    summary.print();
    summary.write_csv("fig8_summary")?;
    headline.print();
    headline.write_csv("fig8_headline")?;
    println!("\nseries CSVs in bench_out/fig8_*  (concurrency, p90 TTFT, queue time)");
    Ok(())
}
