//! Figure 9 — median TPOT and peak generation throughput per model/system
//! on the bursty trace (simulated 8×H200; same trace as Fig 8).

use flying_serving::sim::{simulate, CostModel, HwSpec, PaperModel, SimConfig, SimSystem};
use flying_serving::util::bench::Table;
use flying_serving::workload::{generate, WorkloadCfg};

fn main() -> anyhow::Result<()> {
    let models = [
        PaperModel::llama70b(),
        PaperModel::gptoss120b(),
        PaperModel::nemotron8b(),
    ];
    let systems = [
        SimSystem::StaticDp,
        SimSystem::StaticTp(8),
        SimSystem::Shift,
        SimSystem::Flying,
    ];

    let mut t = Table::new(
        "Fig 9 — median TPOT / peak generation throughput (sim 8xH200)",
        &["model", "system", "median TPOT (ms)", "peak throughput (tok/s)"],
    );
    let mut ratios = Table::new(
        "Fig 9 ratios (paper: TPOT_dp/TPOT_fly 1.28-2.31x; fly ~95% of DP peak; fly/tp peak 2.0-2.5x)",
        &["model", "TPOT dp/fly", "peak fly/dp", "peak fly/tp", "peak fly/shift"],
    );

    for model in models {
        let name = model.name;
        let cm = CostModel::new(HwSpec::default(), model);
        let mut wl = WorkloadCfg::paper_full(4242, 800);
        let sat = cm.tp_saturation_rps(2064, 288); // see fig8 bench
        wl.low_rate = (0.12 * sat, 0.30 * sat);
        wl.high_rate = (0.60 * sat, 1.20 * sat);
        let trace = generate(&wl);
        let mut tpot = std::collections::BTreeMap::new();
        let mut peak = std::collections::BTreeMap::new();
        for sys in systems {
            if sys == SimSystem::Shift && name.contains("GPT-OSS") {
                continue;
            }
            let o = simulate(sys, &cm, &trace, &SimConfig::default());
            let s = o.recorder.summary(None);
            t.row(&[
                name.to_string(),
                sys.label().to_string(),
                format!("{:.1}", s.p50_tpot * 1e3),
                format!("{:.0}", s.peak_throughput),
            ]);
            tpot.insert(sys.label(), s.p50_tpot);
            peak.insert(sys.label(), s.peak_throughput);
        }
        let g = |m: &std::collections::BTreeMap<&str, f64>, k: &str| m.get(k).copied().unwrap_or(f64::NAN);
        ratios.row(&[
            name.to_string(),
            format!("{:.2}x", g(&tpot, "static-dp") / g(&tpot, "flying")),
            format!("{:.0}%", 100.0 * g(&peak, "flying") / g(&peak, "static-dp")),
            format!("{:.2}x", g(&peak, "flying") / g(&peak, "static-tp")),
            format!("{:.2}x", g(&peak, "flying") / g(&peak, "shift-parallelism")),
        ]);
    }

    t.print();
    t.write_csv("fig9_tpot_throughput")?;
    ratios.print();
    ratios.write_csv("fig9_ratios")?;
    Ok(())
}
