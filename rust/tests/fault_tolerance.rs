//! Fault-tolerance chaos harness (ISSUE 6): the coordinator under injected
//! engine faults — stalls, slowdowns, dropped replies, permanent death —
//! with the lockstep watchdog on.  The contract these tests enforce:
//!
//! * **no deadlock** — every trace finishes inside a wall-clock bound, even
//!   with engines dying mid-switch;
//! * **no panic** — faults surface as typed degradation, never unwraps;
//! * **conservation** — completed + rejected ids partition the submitted
//!   ids exactly (no request is lost, none is double-reported);
//! * **KV invariants** — every adaptor's block accounting survives
//!   recovery (`Cluster::check_invariants`);
//! * **faults off ≡ baseline** — a fault-free watchdog run is
//!   byte-identical to the pre-watchdog path.
//!
//! ISSUE 8 extends the contract with fail-*recover*: under `recover`,
//! transiently-dead engines rejoin through quarantine + probe, idle
//! capacity heals back to `n_engines`, crash loops re-escalate to
//! permanent fail-stop inside a bounded attempt budget, and with recovery
//! off the revive markers are inert — byte-identical to the PR-6
//! degradation path.
//!
//! ISSUE 9 composes the step pipeline on top: `--overlap` chaos runs
//! (double-buffered prebuilds, async migration collectives, co-issued
//! envelopes) must satisfy the identical contract, and a disabled
//! `OverlapConfig` with armed sub-knobs must be inert under faults.
//!
//! Failures reproduce from the seed alone: `CHAOS_SEED=<n> cargo test`.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use flying_serving::baselines::StaticDpPolicy;
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::{OverlapConfig, Strategy, WatchdogConfig};
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::engine::FaultPlan;
use flying_serving::json::Value;
use flying_serving::kv::KvCacheAdaptor;
use flying_serving::metrics::{FaultStats, Recorder};
use flying_serving::model::{ModelCfg, StaticShapes};
use flying_serving::workload::{synth_prompt_tokens, Priority, Scenario};

fn cfg() -> ModelCfg {
    ModelCfg {
        name: "stub-tiny".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 8,
        ffn_hidden: 48,
        n_experts: 0,
        top_k: 0,
        // More block headroom than the fault-free suite: recovery
        // re-prefills rescued requests, which transiently double-books
        // capacity on the survivors.
        n_blocks: 32,
        block_base: 4,
        max_ctx: 256,
        vocab: 258,
        pool_elems: 16 * 4 * 4 * 8,
    }
}

fn shapes() -> StaticShapes {
    StaticShapes { b_dec: 4, c_prefill: 16 }
}

/// Chaos-test watchdog: total reply budget 150 + 250 + 350 = 750ms, above
/// the 400ms communicator timeout — survivors of a dead peer's collective
/// reply `Err` (comm timeout) before the coordinator would misclassify
/// them as failed too.
fn chaos_watchdog() -> WatchdogConfig {
    WatchdogConfig {
        enabled: true,
        reply_timeout: Duration::from_millis(150),
        retries: 2,
        backoff: Duration::from_millis(100),
        max_request_retries: 2,
        ..WatchdogConfig::default()
    }
}

/// `chaos_watchdog` with fail-recover armed: short rejoin backoff so a
/// whole revive cycle (fault → backoff → respawn → probe) fits inside a
/// compressed chaos trace.
fn recover_watchdog(max_rejoin_attempts: u32, backoff_ms: u64) -> WatchdogConfig {
    WatchdogConfig {
        recover: true,
        max_rejoin_attempts,
        rejoin_backoff: Duration::from_millis(backoff_ms),
        ..chaos_watchdog()
    }
}

const CHAOS_COMM_TIMEOUT: Duration = Duration::from_millis(400);

fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        id,
        prompt: synth_prompt_tokens(id, prompt_len),
        max_new,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    }
}

/// Shrink a simulator-scale scenario trace onto the stub testbed: tiny
/// prompts/outputs, arrivals compressed into ~1 wall-clock second.  The
/// arrival *order* and the priority/TP-demand mix survive — that is what
/// the chaos runs stress.
fn scenario_trace(sc: Scenario, seed: u64, n: usize) -> Vec<ServeRequest> {
    let raw = sc.generate(seed, n);
    let span = raw.last().map(|r| r.arrival).unwrap_or(0.0).max(1e-9);
    raw.iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: synth_prompt_tokens(r.id, r.prompt_len.clamp(1, 24)),
            max_new: r.output_len.clamp(1, 6),
            priority: r.priority,
            tp_demand: r.tp_demand,
            arrival: r.arrival / span,
        })
        .collect()
}

/// Conservation: completed ∪ rejected must equal the submitted ids with no
/// overlap — a recovered request ends up on exactly one side.
fn assert_conserved(tag: &str, submitted: &BTreeSet<u64>, outcome: &flying_serving::coordinator::ClusterOutcome) {
    let done: BTreeSet<u64> = outcome.outputs.keys().copied().collect();
    let rejected: BTreeSet<u64> = outcome.rejected.iter().copied().collect();
    assert!(
        done.is_disjoint(&rejected),
        "{tag}: ids both completed and rejected: {:?}",
        done.intersection(&rejected).collect::<Vec<_>>()
    );
    let all: BTreeSet<u64> = done.union(&rejected).copied().collect();
    assert_eq!(
        &all, submitted,
        "{tag}: request conservation violated (lost: {:?}, invented: {:?})",
        submitted.difference(&all).collect::<Vec<_>>(),
        all.difference(submitted).collect::<Vec<_>>()
    );
}

/// Dump a chaos run's journal to `bench_out/chaos_trace.jsonl` (appending)
/// — written *before* any assertion so a failing run leaves the trace
/// behind for the CI failure artifact.
fn append_chaos_trace(c: &Cluster, meta: Value) {
    use std::io::Write as _;
    let _ = std::fs::create_dir_all("bench_out");
    let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_out/chaos_trace.jsonl")
    else {
        return; // best-effort: the dump must never fail the test itself
    };
    let _ = c.journal().write_jsonl(&mut f, Some(&meta));
    let _ = f.flush();
}

/// The tentpole gate: every scenario in the library, four engines, a fresh
/// randomized fault plan per engine — the run must terminate, conserve
/// every request, and keep KV accounting exact, whatever the plans do.
#[test]
fn chaos_randomized_all_scenarios() {
    let seed = chaos_seed();
    // Fresh trace file per test invocation; runs below append to it.
    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::File::create("bench_out/chaos_trace.jsonl");
    let strategies = [Strategy::Sequential, Strategy::SoftPreempt, Strategy::HardPreempt];
    for (i, sc) in Scenario::ALL.into_iter().enumerate() {
        let t0 = Instant::now();
        let run_seed = seed.wrapping_add(i as u64);
        let plans: Vec<FaultPlan> =
            (0..4).map(|e| FaultPlan::randomized(run_seed, e)).collect();
        let trace = scenario_trace(sc, run_seed, 36);
        let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();
        let strategy = strategies[i % strategies.len()];
        let tag = format!("{sc} seed={run_seed:#x} strategy={}", strategy.name());

        let mut c = Cluster::start_stub_with(cfg(), shapes(), 4, CHAOS_COMM_TIMEOUT, &plans)
            .unwrap_or_else(|e| panic!("{tag}: start: {e:#}"));
        c.set_watchdog(chaos_watchdog());
        c.set_trace(true);
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), strategy)
            .unwrap_or_else(|e| panic!("{tag}: run_trace must degrade, not error: {e:#}"));
        append_chaos_trace(
            &c,
            Value::obj(vec![
                ("run", Value::str(tag.clone())),
                ("dropped", Value::num(c.journal().dropped() as f64)),
            ]),
        );

        assert_conserved(&tag, &submitted, &out);
        c.check_invariants()
            .unwrap_or_else(|e| panic!("{tag}: KV invariants: {e:#}"));
        // Fail-stop bookkeeping is consistent: engines either faulted and
        // are masked out, or the stats say nothing happened.
        let stats = c.fault_stats();
        assert_eq!(
            c.failed_mask().count_ones() as usize,
            stats.engine_faults,
            "{tag}: failed mask vs fault count"
        );
        c.shutdown(); // must not hang on dead engines
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(30),
            "{tag}: chaos run took {elapsed:?} — lockstep stalled instead of degrading"
        );
    }
}

/// Engine death exactly mid-switch (the acceptance scenario): a DP
/// resident opens a drain for an explicit-TP request, then the group's
/// second member dies.  The group must dissolve to the survivor, the dead
/// engine's work must be recovered or rejected — and the coordinator must
/// come out with exact conservation and clean KV accounting.
#[test]
fn engine_death_mid_switch_dissolves_group_and_recovers() {
    let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
    // Engine 1 dies a few commands in: after the residents' first steps,
    // while the TP-2 drain (which needs both engines) is still pending.
    plans[1].die_at = Some(6);

    let mut trace = vec![req(1, 16, 10), req(2, 12, 8)];
    let mut tp = req(3, 10, 3);
    tp.tp_demand = Some(2);
    tp.arrival = 0.05;
    trace.push(tp);
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("death mid-switch must degrade, not error");

    assert_conserved("death-mid-switch", &submitted, &out);
    let stats = c.fault_stats();
    assert!(stats.engine_faults >= 1, "engine 1's death was never detected");
    assert_eq!(c.failed_mask() & 0b10, 0b10, "engine 1 must be fail-stopped");
    // The TP-2 request can never bind with one of two engines dead: it is
    // either served before the death lands or rejected — never stranded.
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "death mid-switch stalled: {:?}",
        t0.elapsed()
    );
}

/// Hard differential gate: with the watchdog enabled but no faults
/// injected, outputs and rejections are identical to the pre-watchdog
/// blocking path, and every fault counter stays zero.
#[test]
fn faults_off_is_byte_identical_to_baseline() {
    let mk_trace = || {
        let mut trace: Vec<ServeRequest> = (1..=4).map(|i| req(i, 8 + i as usize, 4)).collect();
        let mut tp = req(5, 12, 5);
        tp.tp_demand = Some(2);
        trace.push(tp);
        trace
    };

    // Baseline: the default cluster, watchdog off (blocking collection).
    let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
    let base = c
        .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
        .unwrap();
    assert_eq!(c.fault_stats(), FaultStats::default());
    c.shutdown();

    // Watchdog on, empty fault plans: the watched collect path publishes
    // results — token values, completion set, rejections must not move.
    let mut c = Cluster::start_stub_with(cfg(), shapes(), 2, Duration::from_secs(30), &[]).unwrap();
    c.set_watchdog(WatchdogConfig { enabled: true, ..WatchdogConfig::default() });
    let watched = c
        .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
        .unwrap();
    assert_eq!(base.outputs, watched.outputs, "watchdog changed token values");
    assert_eq!(base.rejected, watched.rejected);
    assert_eq!(
        watched.fault_stats,
        FaultStats::default(),
        "fault-free run must not count faults"
    );
    assert_eq!(c.failed_mask(), 0);
    c.shutdown();
}

/// Satellite (d): generational KV handles tolerate staleness — releasing
/// through a dead engine's recovery path must skip (never panic, never
/// touch a recycled slot), and the pool accounting stays exact.
#[test]
fn stale_kv_handle_release_skips_never_panics() {
    let mut ad = KvCacheAdaptor::new(cfg());
    let h1 = ad.register(1, 1).unwrap();
    ad.ensure_capacity_h(h1, 10).unwrap();
    let used = ad.used_blocks();
    assert!(used > 0);

    // Live release succeeds and frees the blocks.
    assert!(ad.release_if_live_h(h1), "live handle must release");
    assert_eq!(ad.used_blocks(), 0);

    // The handle is now stale; a second recovery pass over the same engine
    // must no-op — even after the slot is recycled by a new request.
    assert!(!ad.release_if_live_h(h1), "stale handle must be skipped");
    let h2 = ad.register(2, 1).unwrap();
    ad.ensure_capacity_h(h2, 6).unwrap();
    let used2 = ad.used_blocks();
    assert!(!ad.release_if_live_h(h1), "stale handle must not hit the recycled slot");
    assert_eq!(ad.used_blocks(), used2, "stale release disturbed a live request");
    assert!(ad.request_h(h2).is_some());
    ad.check_invariants().unwrap();
}

/// Satellite (d), PR 3 regression: a speculative request that *completes*
/// while the drain it rode is still open must publish its tokens and leave
/// the group able to settle — identically with the watchdog on and off.
#[test]
fn mid_drain_speculative_completion_consistent_under_watchdog() {
    // Four long DP residents hold the drain open; the explicit-TP request
    // is short enough to finish speculatively before promotion.
    let mk_trace = || {
        let mut trace: Vec<ServeRequest> = (1..=4).map(|i| req(i, 8, 10)).collect();
        let mut tp = req(5, 8, 2);
        tp.tp_demand = Some(2);
        trace.push(tp);
        trace
    };
    let run = |watchdog: bool| {
        let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
        if watchdog {
            c.set_watchdog(WatchdogConfig { enabled: true, ..WatchdogConfig::default() });
        }
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
            .unwrap();
        c.check_invariants().unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs.len(), 5);
    assert_eq!(off.outputs[&5].len(), 2, "speculative request must complete mid-drain");
    assert_eq!(off.outputs, on.outputs, "watchdog changed mid-drain completion");
    assert!(off.rejected.is_empty() && on.rejected.is_empty());

    // The completed tokens match an undisturbed static run — the suite's
    // core invariant, here across a mid-drain speculative completion.
    let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
    let solo = c
        .run_trace(vec![req(5, 8, 2)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(off.outputs[&5], solo.outputs[&5]);
}

/// Recovery budget: a request rescued more times than
/// `max_request_retries` is rejected, not retried forever.  With every
/// engine eventually dead there is nowhere left to recover to — the run
/// must still terminate with all ids accounted for.
#[test]
fn all_engines_dead_terminates_with_everything_accounted() {
    let plans: Vec<FaultPlan> = (0..2)
        .map(|e| FaultPlan { die_at: Some(4 + 2 * e as u64), ..FaultPlan::none() })
        .collect();
    let trace = vec![req(1, 16, 12), req(2, 12, 12)];
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("total cluster death must degrade, not error");
    assert_conserved("all-dead", &submitted, &out);
    assert_eq!(c.failed_mask(), 0b11, "both engines must be fail-stopped");
    assert!(
        c.fault_stats().requests_aborted >= out.rejected.len(),
        "rejections under total death must be charged to the abort counter"
    );
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "total-death run stalled: {:?}",
        t0.elapsed()
    );
}

/// ISSUE 7 satellite: every `FaultStats` counter is paired 1:1 with a
/// journal event at its increment site, so on a scripted fault plan the
/// end-of-run counters and the flight recorder's event counts must agree
/// exactly — the journal is an audit log of the stats, not an estimate.
#[test]
fn fault_stats_counters_match_journal_events() {
    let plans: Vec<FaultPlan> = (0..2)
        .map(|e| FaultPlan { die_at: Some(4 + 2 * e as u64), ..FaultPlan::none() })
        .collect();
    let trace = vec![req(1, 16, 12), req(2, 12, 12)];
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    c.set_trace(true);
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("scripted death must degrade, not error");
    assert_conserved("stats-vs-journal", &submitted, &out);

    let stats = c.fault_stats();
    let j = c.journal();
    assert!(j.is_enabled());
    assert_eq!(j.dropped(), 0, "ring overflowed — counts below would undercount");
    let counts = j.counts();
    let n = |k: &str| counts.get(k).copied().unwrap_or(0);
    assert_eq!(stats.engine_faults, n("engine_fault"), "{counts:?}");
    assert_eq!(stats.reply_timeouts, n("watchdog_timeout"), "{counts:?}");
    assert_eq!(stats.stalls_ridden_out, n("watchdog_retry"), "{counts:?}");
    assert_eq!(stats.step_errors, n("step_error"), "{counts:?}");
    assert_eq!(stats.requests_recovered, n("request_recovered"), "{counts:?}");
    assert_eq!(stats.requests_aborted, n("request_aborted"), "{counts:?}");
    // The scripted deaths must actually have produced faults to audit, and
    // each death degrades its engine exactly once.
    assert_eq!(stats.engine_faults, 2, "both scripted deaths must escalate");
    assert_eq!(n("engine_degraded"), 2, "{counts:?}");
    c.check_invariants().unwrap();
    c.shutdown();
}

/// ISSUE 8 tentpole gate: kill-then-revive chaos across every scenario.
/// Randomized fault plans with every death forced transient, recovery
/// armed — each run must terminate, conserve every request, and *heal*:
/// after rejoins quiesce, no engine is failed or quarantined and idle
/// capacity is back to all four engines.
#[test]
fn chaos_kill_then_revive_all_scenarios() {
    let seed = chaos_seed();
    let strategies = [Strategy::Sequential, Strategy::SoftPreempt, Strategy::HardPreempt];
    for (i, sc) in Scenario::ALL.into_iter().enumerate() {
        let t0 = Instant::now();
        // Offset from the recover-off sweep so the two chaos tests explore
        // different plan draws under the same CHAOS_SEED.
        let run_seed = seed.wrapping_add(0x5EC0).wrapping_add(i as u64);
        let plans: Vec<FaultPlan> = (0..4)
            .map(|e| {
                let mut p = FaultPlan::randomized(run_seed, e);
                // Every death is transient and revives healthy, and dropped
                // replies (which escalate to a *permanent* timeout fault
                // with no death to revive) are stripped: the healing
                // assertion below needs every fault to be recoverable.
                // Stalls and slowdowns stay in — recovery must coexist
                // with the ride-out paths.
                if p.die_at.is_some() {
                    p.revive_after = Some(0);
                }
                p.drop_reply_at.clear();
                p
            })
            .collect();
        let trace = scenario_trace(sc, run_seed, 36);
        let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();
        let strategy = strategies[i % strategies.len()];
        let tag = format!("revive {sc} seed={run_seed:#x} strategy={}", strategy.name());

        let mut c = Cluster::start_stub_with(cfg(), shapes(), 4, CHAOS_COMM_TIMEOUT, &plans)
            .unwrap_or_else(|e| panic!("{tag}: start: {e:#}"));
        c.set_watchdog(recover_watchdog(3, 20));
        c.set_trace(true);
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), strategy)
            .unwrap_or_else(|e| panic!("{tag}: run_trace must recover, not error: {e:#}"));
        // The trace can complete on the survivors while a backoff clock is
        // still ticking; quiesce the rejoin queue before asserting health.
        c.drive_rejoins_to_quiescence(&mut Recorder::new())
            .unwrap_or_else(|e| panic!("{tag}: rejoin quiescence: {e:#}"));
        append_chaos_trace(
            &c,
            Value::obj(vec![
                ("run", Value::str(tag.clone())),
                ("dropped", Value::num(c.journal().dropped() as f64)),
            ]),
        );

        assert_conserved(&tag, &submitted, &out);
        c.check_invariants()
            .unwrap_or_else(|e| panic!("{tag}: KV invariants: {e:#}"));
        // Healing: every transient death was revived, probed, and
        // readmitted — the cluster ends with full idle capacity.
        assert_eq!(c.failed_mask(), 0, "{tag}: transient deaths must all heal");
        assert_eq!(c.quarantined_mask(), 0, "{tag}: no engine may be stuck in quarantine");
        assert_eq!(c.idle_count(), 4, "{tag}: idle capacity must heal to n_engines");
        let stats = c.fault_stats();
        assert_eq!(stats.rejoins_abandoned, 0, "{tag}: healthy revives must not abandon");
        assert_eq!(
            stats.engine_revives, stats.rejoin_probes,
            "{tag}: every revive is probed exactly once"
        );
        assert_eq!(
            stats.rejoin_probes, stats.rejoins_ok,
            "{tag}: healthy incarnations must pass their probe"
        );
        assert_eq!(
            stats.engine_revives, stats.engine_faults,
            "{tag}: every fault is a revived death, so counts pair 1:1"
        );
        // Journal audit (skipped only if the ring overflowed, which these
        // 36-request traces do not approach).
        if c.journal().dropped() == 0 {
            let counts = c.journal().counts();
            let n = |k: &str| counts.get(k).copied().unwrap_or(0);
            assert_eq!(stats.engine_revives, n("engine_revive"), "{tag}: {counts:?}");
            assert_eq!(stats.rejoin_probes, n("rejoin_probe"), "{tag}: {counts:?}");
            assert_eq!(stats.rejoins_ok, n("rejoin_ok"), "{tag}: {counts:?}");
            assert_eq!(stats.rejoins_abandoned, n("rejoin_abandoned"), "{tag}: {counts:?}");
        }
        c.shutdown();
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(30),
            "{tag}: revive chaos took {elapsed:?} — recovery stalled the trace"
        );
    }
}

/// Directed revive of the acceptance scenario: the engine that died
/// mid-switch comes back.  The revive sequence must run end to end —
/// generation bump, communicator rejoin, fresh KV adaptor, quarantine
/// probe, scheduler readmission — and the journal must audit each stage
/// exactly once.
#[test]
fn revive_mid_switch_rejoins_and_heals() {
    let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
    plans[1].die_at = Some(6);
    plans[1].revive_after = Some(0); // transient: revives healthy

    let mut trace = vec![req(1, 16, 10), req(2, 12, 8)];
    let mut tp = req(3, 10, 3);
    tp.tp_demand = Some(2);
    tp.arrival = 0.05;
    trace.push(tp);
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(recover_watchdog(3, 10));
    c.set_trace(true);
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("revive mid-switch must recover, not error");
    c.drive_rejoins_to_quiescence(&mut Recorder::new()).unwrap();

    assert_conserved("revive-mid-switch", &submitted, &out);
    let stats = c.fault_stats();
    assert_eq!(stats.engine_faults, 1, "exactly one scripted death");
    assert_eq!(stats.engine_revives, 1, "the death must be revived exactly once");
    assert_eq!(stats.rejoin_probes, 1);
    assert_eq!(stats.rejoins_ok, 1, "a healthy incarnation must pass its probe");
    assert_eq!(stats.rejoins_abandoned, 0);
    assert_eq!(c.failed_mask(), 0, "engine 1 must be healed, not fail-stopped");
    assert_eq!(c.quarantined_mask(), 0);
    assert_eq!(c.idle_count(), 2, "idle capacity must heal to both engines");
    assert_eq!(c.engine_generation(0), 0, "the survivor keeps its original incarnation");
    assert_eq!(c.engine_generation(1), 1, "the revived engine is generation-bumped");
    // Journal audit of the revive sequence, stage by stage.
    let j = c.journal();
    assert_eq!(j.dropped(), 0);
    let counts = j.counts();
    let n = |k: &str| counts.get(k).copied().unwrap_or(0);
    assert_eq!(n("engine_revive"), 1, "{counts:?}");
    assert_eq!(n("rejoin_probe"), 1, "{counts:?}");
    assert_eq!(n("rejoin_ok"), 1, "{counts:?}");
    assert_eq!(n("rejoin_abandoned"), 0, "{counts:?}");
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "revive mid-switch stalled: {:?}",
        t0.elapsed()
    );
}

/// Crash-loop anti-livelock: an engine whose every incarnation dies again
/// must exhaust the cumulative rejoin-attempt budget and re-escalate to
/// *permanent* fail-stop — recovery may never retry forever.  Driven via
/// `step_once` with a trickle of work so each revived incarnation is
/// actually handed the command that kills it.
#[test]
fn crash_loop_reescalates_to_permanent_fail_stop() {
    let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
    plans[1].die_at = Some(2);
    // Every revived incarnation dies on its first post-probe command.
    plans[1].revive_after = Some(1);

    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(recover_watchdog(2, 5));
    let mut rec = Recorder::new();
    let mut policy = FlyingPolicy::default();
    let mut next_id = 1u64;
    let t0 = Instant::now();
    while c.fault_stats().rejoins_abandoned == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "crash loop never abandoned: {:?}",
            c.fault_stats()
        );
        // Keep work flowing: whenever an engine is idle, feed it a short
        // request — a rejoined crash-looper gets bound (least-loaded) and
        // promptly dies again, burning one attempt per cycle.
        if c.idle_count() > 0 && next_id <= 512 {
            c.submit(req(next_id, 6, 2), &mut rec);
            next_id += 1;
        }
        let stepped = c
            .step_once(&mut policy, Strategy::Sequential, &mut rec)
            .expect("crash loop must degrade, not error");
        if !stepped {
            // Nothing runnable: let the rejoin backoff clocks mature.
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    let stats = c.fault_stats();
    assert_eq!(stats.rejoins_abandoned, 1, "abandonment is terminal, once");
    assert_eq!(stats.engine_revives, 2, "the budget allows exactly 2 attempts");
    assert_eq!(stats.rejoin_probes, 2);
    assert_eq!(stats.rejoins_ok, 2, "probes pass; the crash fires on real work");
    assert_eq!(
        stats.engine_faults, 3,
        "original death + one per crash-looping incarnation"
    );
    assert_eq!(c.failed_mask() & 0b10, 0b10, "engine 1 ends permanently fail-stopped");
    assert_eq!(c.quarantined_mask(), 0);
    assert_eq!(c.engine_generation(1), 2, "two respawns were attempted");
    assert!(
        !c.rejoin_pending(),
        "an abandoned engine must leave the rejoin queue for good"
    );
    // Quiescence is already reached: this must return without reviving.
    c.drive_rejoins_to_quiescence(&mut rec).unwrap();
    assert_eq!(c.fault_stats().engine_revives, 2, "abandoned engines stay down");
    c.check_invariants().unwrap();
    c.shutdown();
}

/// Differential gate for the new flag: with recovery *off*, `revive_after`
/// markers are inert — outputs, rejections, and every fault counter are
/// byte-identical to the same plans with the markers stripped, no engine
/// is ever respawned, and the PR-6 degradation endstate is unchanged.
#[test]
fn recover_off_ignores_revive_markers_byte_identical() {
    let mk_trace = || {
        let mut trace = vec![req(1, 16, 10), req(2, 12, 8)];
        let mut tp = req(3, 10, 3);
        tp.tp_demand = Some(2);
        tp.arrival = 0.05;
        trace.push(tp);
        trace
    };
    let run = |revive_marker: bool| {
        let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
        plans[1].die_at = Some(6);
        if revive_marker {
            plans[1].revive_after = Some(0);
        }
        let mut c =
            Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
        c.set_watchdog(chaos_watchdog()); // recover stays off
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::Sequential)
            .unwrap();
        assert_eq!(c.engine_generation(1), 0, "recover off must never respawn");
        assert_eq!(c.failed_mask() & 0b10, 0b10, "death stays permanent");
        assert!(!c.rejoin_pending(), "recover off must never queue rejoins");
        c.check_invariants().unwrap();
        c.shutdown();
        out
    };
    let marked = run(true);
    let plain = run(false);
    assert_eq!(marked.outputs, plain.outputs, "revive marker changed token values");
    assert_eq!(marked.rejected, plain.rejected);
    assert_eq!(marked.fault_stats, plain.fault_stats);
    assert_eq!(marked.fault_stats.engine_revives, 0);
    assert_eq!(marked.fault_stats.rejoin_probes, 0);
    assert_eq!(marked.fault_stats.rejoins_ok, 0);
    assert_eq!(marked.fault_stats.rejoins_abandoned, 0);
}

/// ISSUE 9 chaos composition: overlap × watchdog × recover.  Kill-then-
/// revive chaos across every scenario with the step pipeline armed on top
/// of the recovery stack — double-buffered prebuilds go stale across
/// faults, async migration collectives complete against revived
/// incarnations, co-issued envelopes die mid-flight.  The contract is the
/// same as the recovery tentpole: terminate, conserve every request, keep
/// KV accounting exact, and heal back to full idle capacity.
#[test]
fn chaos_overlap_kill_then_revive_all_scenarios() {
    let seed = chaos_seed();
    let strategies = [Strategy::Sequential, Strategy::SoftPreempt, Strategy::HardPreempt];
    for (i, sc) in Scenario::ALL.into_iter().enumerate() {
        let t0 = Instant::now();
        // Offset from both earlier chaos sweeps so the three explore
        // different plan draws under the same CHAOS_SEED.
        let run_seed = seed.wrapping_add(0x09_1A90).wrapping_add(i as u64);
        let plans: Vec<FaultPlan> = (0..4)
            .map(|e| {
                let mut p = FaultPlan::randomized(run_seed, e);
                if p.die_at.is_some() {
                    p.revive_after = Some(0);
                }
                p.drop_reply_at.clear();
                p
            })
            .collect();
        let trace = scenario_trace(sc, run_seed, 36);
        let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();
        let strategy = strategies[i % strategies.len()];
        let tag = format!("overlap {sc} seed={run_seed:#x} strategy={}", strategy.name());

        let mut c = Cluster::start_stub_with(cfg(), shapes(), 4, CHAOS_COMM_TIMEOUT, &plans)
            .unwrap_or_else(|e| panic!("{tag}: start: {e:#}"));
        c.set_watchdog(recover_watchdog(3, 20));
        c.set_overlap_config(OverlapConfig { enabled: true, ..OverlapConfig::default() });
        c.set_trace(true);
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), strategy)
            .unwrap_or_else(|e| panic!("{tag}: run_trace must recover, not error: {e:#}"));
        c.drive_rejoins_to_quiescence(&mut Recorder::new())
            .unwrap_or_else(|e| panic!("{tag}: rejoin quiescence: {e:#}"));
        append_chaos_trace(
            &c,
            Value::obj(vec![
                ("run", Value::str(tag.clone())),
                ("dropped", Value::num(c.journal().dropped() as f64)),
            ]),
        );

        assert_conserved(&tag, &submitted, &out);
        c.check_invariants()
            .unwrap_or_else(|e| panic!("{tag}: KV invariants: {e:#}"));
        assert_eq!(c.failed_mask(), 0, "{tag}: transient deaths must all heal");
        assert_eq!(c.quarantined_mask(), 0, "{tag}: no engine may be stuck in quarantine");
        assert_eq!(c.idle_count(), 4, "{tag}: idle capacity must heal to n_engines");
        c.shutdown();
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(30),
            "{tag}: overlap chaos took {elapsed:?} — the pipeline stalled recovery"
        );
    }
}

/// ISSUE 9 differential gate on the real path: an `OverlapConfig` with all
/// sub-knobs armed but the master switch off must be completely inert —
/// outputs, rejections, and every fault counter byte-identical to an
/// untouched cluster, under a scripted mid-switch death.  This is what
/// makes `--overlap` safe to carry: every pipeline branch is gated on
/// `enabled && <knob>`, never on a sub-knob alone.
#[test]
fn overlap_disabled_with_armed_subknobs_is_inert_under_faults() {
    let mk_trace = || {
        let mut trace = vec![req(1, 16, 10), req(2, 12, 8)];
        let mut tp = req(3, 10, 3);
        tp.tp_demand = Some(2);
        tp.arrival = 0.05;
        trace.push(tp);
        trace
    };
    let run = |set_cfg: bool| {
        let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
        plans[1].die_at = Some(6);
        let mut c =
            Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
        c.set_watchdog(chaos_watchdog());
        if set_cfg {
            // Sub-knobs all true (their default), master off: inert.
            c.set_overlap_config(OverlapConfig { enabled: false, ..OverlapConfig::default() });
        }
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::Sequential)
            .unwrap();
        c.check_invariants().unwrap();
        c.shutdown();
        out
    };
    let configured = run(true);
    let untouched = run(false);
    assert_eq!(configured.outputs, untouched.outputs, "disabled overlap changed tokens");
    assert_eq!(configured.rejected, untouched.rejected);
    assert_eq!(configured.fault_stats, untouched.fault_stats);
}

/// ISSUE 8 satellite: the stranded-rejection sweep threshold (a hard-coded
/// `1_000` before this PR) is a config field.  With a tiny threshold the
/// sweep still only counts *idle* iterations — work that is progressing on
/// the survivor completes untouched, while the unbindable TP-2 request is
/// swept into rejection instead of hanging the trace.
#[test]
fn stranded_sweep_threshold_is_configurable() {
    let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
    plans[1].die_at = Some(2); // dies early, before the TP drain can bind

    let mut trace = vec![req(1, 8, 12), req(2, 8, 12)];
    let mut tp = req(3, 10, 3);
    tp.tp_demand = Some(2);
    tp.arrival = 0.05;
    trace.push(tp);
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(WatchdogConfig { stranded_sweep_iters: 25, ..chaos_watchdog() });
    assert_eq!(c.watchdog().stranded_sweep_iters, 25, "knob must plumb through");
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("stranded sweep must degrade, not error");

    assert_conserved("stranded-sweep", &submitted, &out);
    assert!(
        out.rejected.contains(&3),
        "TP-2 demand with one of two engines dead must be swept into rejection"
    );
    assert!(
        out.outputs.contains_key(&1),
        "a tiny sweep threshold must not reject requests that are progressing"
    );
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "a 25-iteration sweep threshold must terminate promptly: {:?}",
        t0.elapsed()
    );
}
