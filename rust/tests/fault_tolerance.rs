//! Fault-tolerance chaos harness (ISSUE 6): the coordinator under injected
//! engine faults — stalls, slowdowns, dropped replies, permanent death —
//! with the lockstep watchdog on.  The contract these tests enforce:
//!
//! * **no deadlock** — every trace finishes inside a wall-clock bound, even
//!   with engines dying mid-switch;
//! * **no panic** — faults surface as typed degradation, never unwraps;
//! * **conservation** — completed + rejected ids partition the submitted
//!   ids exactly (no request is lost, none is double-reported);
//! * **KV invariants** — every adaptor's block accounting survives
//!   recovery (`Cluster::check_invariants`);
//! * **faults off ≡ baseline** — a fault-free watchdog run is
//!   byte-identical to the pre-watchdog path.
//!
//! Failures reproduce from the seed alone: `CHAOS_SEED=<n> cargo test`.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use flying_serving::baselines::StaticDpPolicy;
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::{Strategy, WatchdogConfig};
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::engine::FaultPlan;
use flying_serving::json::Value;
use flying_serving::kv::KvCacheAdaptor;
use flying_serving::metrics::FaultStats;
use flying_serving::model::{ModelCfg, StaticShapes};
use flying_serving::workload::{synth_prompt_tokens, Priority, Scenario};

fn cfg() -> ModelCfg {
    ModelCfg {
        name: "stub-tiny".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 8,
        ffn_hidden: 48,
        n_experts: 0,
        top_k: 0,
        // More block headroom than the fault-free suite: recovery
        // re-prefills rescued requests, which transiently double-books
        // capacity on the survivors.
        n_blocks: 32,
        block_base: 4,
        max_ctx: 256,
        vocab: 258,
        pool_elems: 16 * 4 * 4 * 8,
    }
}

fn shapes() -> StaticShapes {
    StaticShapes { b_dec: 4, c_prefill: 16 }
}

/// Chaos-test watchdog: total reply budget 150 + 250 + 350 = 750ms, above
/// the 400ms communicator timeout — survivors of a dead peer's collective
/// reply `Err` (comm timeout) before the coordinator would misclassify
/// them as failed too.
fn chaos_watchdog() -> WatchdogConfig {
    WatchdogConfig {
        enabled: true,
        reply_timeout: Duration::from_millis(150),
        retries: 2,
        backoff: Duration::from_millis(100),
        max_request_retries: 2,
    }
}

const CHAOS_COMM_TIMEOUT: Duration = Duration::from_millis(400);

fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        id,
        prompt: synth_prompt_tokens(id, prompt_len),
        max_new,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    }
}

/// Shrink a simulator-scale scenario trace onto the stub testbed: tiny
/// prompts/outputs, arrivals compressed into ~1 wall-clock second.  The
/// arrival *order* and the priority/TP-demand mix survive — that is what
/// the chaos runs stress.
fn scenario_trace(sc: Scenario, seed: u64, n: usize) -> Vec<ServeRequest> {
    let raw = sc.generate(seed, n);
    let span = raw.last().map(|r| r.arrival).unwrap_or(0.0).max(1e-9);
    raw.iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: synth_prompt_tokens(r.id, r.prompt_len.clamp(1, 24)),
            max_new: r.output_len.clamp(1, 6),
            priority: r.priority,
            tp_demand: r.tp_demand,
            arrival: r.arrival / span,
        })
        .collect()
}

/// Conservation: completed ∪ rejected must equal the submitted ids with no
/// overlap — a recovered request ends up on exactly one side.
fn assert_conserved(tag: &str, submitted: &BTreeSet<u64>, outcome: &flying_serving::coordinator::ClusterOutcome) {
    let done: BTreeSet<u64> = outcome.outputs.keys().copied().collect();
    let rejected: BTreeSet<u64> = outcome.rejected.iter().copied().collect();
    assert!(
        done.is_disjoint(&rejected),
        "{tag}: ids both completed and rejected: {:?}",
        done.intersection(&rejected).collect::<Vec<_>>()
    );
    let all: BTreeSet<u64> = done.union(&rejected).copied().collect();
    assert_eq!(
        &all, submitted,
        "{tag}: request conservation violated (lost: {:?}, invented: {:?})",
        submitted.difference(&all).collect::<Vec<_>>(),
        all.difference(submitted).collect::<Vec<_>>()
    );
}

/// Dump a chaos run's journal to `bench_out/chaos_trace.jsonl` (appending)
/// — written *before* any assertion so a failing run leaves the trace
/// behind for the CI failure artifact.
fn append_chaos_trace(c: &Cluster, meta: Value) {
    use std::io::Write as _;
    let _ = std::fs::create_dir_all("bench_out");
    let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_out/chaos_trace.jsonl")
    else {
        return; // best-effort: the dump must never fail the test itself
    };
    let _ = c.journal().write_jsonl(&mut f, Some(&meta));
    let _ = f.flush();
}

/// The tentpole gate: every scenario in the library, four engines, a fresh
/// randomized fault plan per engine — the run must terminate, conserve
/// every request, and keep KV accounting exact, whatever the plans do.
#[test]
fn chaos_randomized_all_scenarios() {
    let seed = chaos_seed();
    // Fresh trace file per test invocation; runs below append to it.
    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::File::create("bench_out/chaos_trace.jsonl");
    let strategies = [Strategy::Sequential, Strategy::SoftPreempt, Strategy::HardPreempt];
    for (i, sc) in Scenario::ALL.into_iter().enumerate() {
        let t0 = Instant::now();
        let run_seed = seed.wrapping_add(i as u64);
        let plans: Vec<FaultPlan> =
            (0..4).map(|e| FaultPlan::randomized(run_seed, e)).collect();
        let trace = scenario_trace(sc, run_seed, 36);
        let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();
        let strategy = strategies[i % strategies.len()];
        let tag = format!("{sc} seed={run_seed:#x} strategy={}", strategy.name());

        let mut c = Cluster::start_stub_with(cfg(), shapes(), 4, CHAOS_COMM_TIMEOUT, &plans)
            .unwrap_or_else(|e| panic!("{tag}: start: {e:#}"));
        c.set_watchdog(chaos_watchdog());
        c.set_trace(true);
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), strategy)
            .unwrap_or_else(|e| panic!("{tag}: run_trace must degrade, not error: {e:#}"));
        append_chaos_trace(
            &c,
            Value::obj(vec![
                ("run", Value::str(tag.clone())),
                ("dropped", Value::num(c.journal().dropped() as f64)),
            ]),
        );

        assert_conserved(&tag, &submitted, &out);
        c.check_invariants()
            .unwrap_or_else(|e| panic!("{tag}: KV invariants: {e:#}"));
        // Fail-stop bookkeeping is consistent: engines either faulted and
        // are masked out, or the stats say nothing happened.
        let stats = c.fault_stats();
        assert_eq!(
            c.failed_mask().count_ones() as usize,
            stats.engine_faults,
            "{tag}: failed mask vs fault count"
        );
        c.shutdown(); // must not hang on dead engines
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(30),
            "{tag}: chaos run took {elapsed:?} — lockstep stalled instead of degrading"
        );
    }
}

/// Engine death exactly mid-switch (the acceptance scenario): a DP
/// resident opens a drain for an explicit-TP request, then the group's
/// second member dies.  The group must dissolve to the survivor, the dead
/// engine's work must be recovered or rejected — and the coordinator must
/// come out with exact conservation and clean KV accounting.
#[test]
fn engine_death_mid_switch_dissolves_group_and_recovers() {
    let mut plans = vec![FaultPlan::none(), FaultPlan::none()];
    // Engine 1 dies a few commands in: after the residents' first steps,
    // while the TP-2 drain (which needs both engines) is still pending.
    plans[1].die_at = Some(6);

    let mut trace = vec![req(1, 16, 10), req(2, 12, 8)];
    let mut tp = req(3, 10, 3);
    tp.tp_demand = Some(2);
    tp.arrival = 0.05;
    trace.push(tp);
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("death mid-switch must degrade, not error");

    assert_conserved("death-mid-switch", &submitted, &out);
    let stats = c.fault_stats();
    assert!(stats.engine_faults >= 1, "engine 1's death was never detected");
    assert_eq!(c.failed_mask() & 0b10, 0b10, "engine 1 must be fail-stopped");
    // The TP-2 request can never bind with one of two engines dead: it is
    // either served before the death lands or rejected — never stranded.
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "death mid-switch stalled: {:?}",
        t0.elapsed()
    );
}

/// Hard differential gate: with the watchdog enabled but no faults
/// injected, outputs and rejections are identical to the pre-watchdog
/// blocking path, and every fault counter stays zero.
#[test]
fn faults_off_is_byte_identical_to_baseline() {
    let mk_trace = || {
        let mut trace: Vec<ServeRequest> = (1..=4).map(|i| req(i, 8 + i as usize, 4)).collect();
        let mut tp = req(5, 12, 5);
        tp.tp_demand = Some(2);
        trace.push(tp);
        trace
    };

    // Baseline: the default cluster, watchdog off (blocking collection).
    let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
    let base = c
        .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
        .unwrap();
    assert_eq!(c.fault_stats(), FaultStats::default());
    c.shutdown();

    // Watchdog on, empty fault plans: the watched collect path publishes
    // results — token values, completion set, rejections must not move.
    let mut c = Cluster::start_stub_with(cfg(), shapes(), 2, Duration::from_secs(30), &[]).unwrap();
    c.set_watchdog(WatchdogConfig { enabled: true, ..WatchdogConfig::default() });
    let watched = c
        .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
        .unwrap();
    assert_eq!(base.outputs, watched.outputs, "watchdog changed token values");
    assert_eq!(base.rejected, watched.rejected);
    assert_eq!(
        watched.fault_stats,
        FaultStats::default(),
        "fault-free run must not count faults"
    );
    assert_eq!(c.failed_mask(), 0);
    c.shutdown();
}

/// Satellite (d): generational KV handles tolerate staleness — releasing
/// through a dead engine's recovery path must skip (never panic, never
/// touch a recycled slot), and the pool accounting stays exact.
#[test]
fn stale_kv_handle_release_skips_never_panics() {
    let mut ad = KvCacheAdaptor::new(cfg());
    let h1 = ad.register(1, 1).unwrap();
    ad.ensure_capacity_h(h1, 10).unwrap();
    let used = ad.used_blocks();
    assert!(used > 0);

    // Live release succeeds and frees the blocks.
    assert!(ad.release_if_live_h(h1), "live handle must release");
    assert_eq!(ad.used_blocks(), 0);

    // The handle is now stale; a second recovery pass over the same engine
    // must no-op — even after the slot is recycled by a new request.
    assert!(!ad.release_if_live_h(h1), "stale handle must be skipped");
    let h2 = ad.register(2, 1).unwrap();
    ad.ensure_capacity_h(h2, 6).unwrap();
    let used2 = ad.used_blocks();
    assert!(!ad.release_if_live_h(h1), "stale handle must not hit the recycled slot");
    assert_eq!(ad.used_blocks(), used2, "stale release disturbed a live request");
    assert!(ad.request_h(h2).is_some());
    ad.check_invariants().unwrap();
}

/// Satellite (d), PR 3 regression: a speculative request that *completes*
/// while the drain it rode is still open must publish its tokens and leave
/// the group able to settle — identically with the watchdog on and off.
#[test]
fn mid_drain_speculative_completion_consistent_under_watchdog() {
    // Four long DP residents hold the drain open; the explicit-TP request
    // is short enough to finish speculatively before promotion.
    let mk_trace = || {
        let mut trace: Vec<ServeRequest> = (1..=4).map(|i| req(i, 8, 10)).collect();
        let mut tp = req(5, 8, 2);
        tp.tp_demand = Some(2);
        trace.push(tp);
        trace
    };
    let run = |watchdog: bool| {
        let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
        if watchdog {
            c.set_watchdog(WatchdogConfig { enabled: true, ..WatchdogConfig::default() });
        }
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::SoftPreempt)
            .unwrap();
        c.check_invariants().unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs.len(), 5);
    assert_eq!(off.outputs[&5].len(), 2, "speculative request must complete mid-drain");
    assert_eq!(off.outputs, on.outputs, "watchdog changed mid-drain completion");
    assert!(off.rejected.is_empty() && on.rejected.is_empty());

    // The completed tokens match an undisturbed static run — the suite's
    // core invariant, here across a mid-drain speculative completion.
    let mut c = Cluster::start_stub(cfg(), shapes(), 2).unwrap();
    let solo = c
        .run_trace(vec![req(5, 8, 2)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(off.outputs[&5], solo.outputs[&5]);
}

/// Recovery budget: a request rescued more times than
/// `max_request_retries` is rejected, not retried forever.  With every
/// engine eventually dead there is nowhere left to recover to — the run
/// must still terminate with all ids accounted for.
#[test]
fn all_engines_dead_terminates_with_everything_accounted() {
    let plans: Vec<FaultPlan> = (0..2)
        .map(|e| FaultPlan { die_at: Some(4 + 2 * e as u64), ..FaultPlan::none() })
        .collect();
    let trace = vec![req(1, 16, 12), req(2, 12, 12)];
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let t0 = Instant::now();
    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("total cluster death must degrade, not error");
    assert_conserved("all-dead", &submitted, &out);
    assert_eq!(c.failed_mask(), 0b11, "both engines must be fail-stopped");
    assert!(
        c.fault_stats().requests_aborted >= out.rejected.len(),
        "rejections under total death must be charged to the abort counter"
    );
    c.check_invariants().unwrap();
    c.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "total-death run stalled: {:?}",
        t0.elapsed()
    );
}

/// ISSUE 7 satellite: every `FaultStats` counter is paired 1:1 with a
/// journal event at its increment site, so on a scripted fault plan the
/// end-of-run counters and the flight recorder's event counts must agree
/// exactly — the journal is an audit log of the stats, not an estimate.
#[test]
fn fault_stats_counters_match_journal_events() {
    let plans: Vec<FaultPlan> = (0..2)
        .map(|e| FaultPlan { die_at: Some(4 + 2 * e as u64), ..FaultPlan::none() })
        .collect();
    let trace = vec![req(1, 16, 12), req(2, 12, 12)];
    let submitted: BTreeSet<u64> = trace.iter().map(|r| r.id).collect();

    let mut c =
        Cluster::start_stub_with(cfg(), shapes(), 2, CHAOS_COMM_TIMEOUT, &plans).unwrap();
    c.set_watchdog(chaos_watchdog());
    c.set_trace(true);
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::Sequential)
        .expect("scripted death must degrade, not error");
    assert_conserved("stats-vs-journal", &submitted, &out);

    let stats = c.fault_stats();
    let j = c.journal();
    assert!(j.is_enabled());
    assert_eq!(j.dropped(), 0, "ring overflowed — counts below would undercount");
    let counts = j.counts();
    let n = |k: &str| counts.get(k).copied().unwrap_or(0);
    assert_eq!(stats.engine_faults, n("engine_fault"), "{counts:?}");
    assert_eq!(stats.reply_timeouts, n("watchdog_timeout"), "{counts:?}");
    assert_eq!(stats.stalls_ridden_out, n("watchdog_retry"), "{counts:?}");
    assert_eq!(stats.step_errors, n("step_error"), "{counts:?}");
    assert_eq!(stats.requests_recovered, n("request_recovered"), "{counts:?}");
    assert_eq!(stats.requests_aborted, n("request_aborted"), "{counts:?}");
    // The scripted deaths must actually have produced faults to audit, and
    // each death degrades its engine exactly once.
    assert_eq!(stats.engine_faults, 2, "both scripted deaths must escalate");
    assert_eq!(n("engine_degraded"), 2, "{counts:?}");
    c.check_invariants().unwrap();
    c.shutdown();
}
