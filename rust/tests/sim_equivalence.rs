//! Differential tests: the event-driven simulator core (`sim::simulate`)
//! must be outcome-equivalent to the preserved loop-based seed
//! implementation (`sim::simulate_reference`) — identical completion sets,
//! identical rejection sets, identical switch counts — on randomized
//! bursty / priority / long-context traces and on scaled-down versions of
//! every bench scenario (fig8/fig9/fig10/table1/table2).
//!
//! Timing-derived metrics (TTFT percentiles etc.) are intentionally NOT
//! compared bit-for-bit: the event core resolves the seed's idle-heartbeat
//! spin differently (by design — see the stall fix), which can shift
//! blocked-idle timestamps by a heartbeat quantum without changing any
//! scheduling decision.

use flying_serving::control::{ControlConfig, ControlRuntime, StaticController};
use flying_serving::sim::{
    outcomes_equivalent, simulate, simulate_adaptive, simulate_reference, CostModel, HwSpec,
    PaperModel, SimConfig, SimSystem,
};
use flying_serving::util::prop::prop_check;
use flying_serving::workload::{generate, Priority, Request, Scenario, WorkloadCfg};

fn check_equivalent(
    system: SimSystem,
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
) -> Result<(), String> {
    let a = simulate(system, cm, trace, cfg);
    let b = simulate_reference(system, cm, trace, cfg);
    outcomes_equivalent(&a, &b).map_err(|e| format!("{}: {e}", system.label()))
}

fn assert_equivalent(system: SimSystem, cm: &CostModel, trace: &[Request], cfg: &SimConfig) {
    if let Err(e) = check_equivalent(system, cm, trace, cfg) {
        panic!("{e}");
    }
}

const ALL_SYSTEMS: [SimSystem; 5] = [
    SimSystem::StaticDp,
    SimSystem::StaticTp(4),
    SimSystem::Shift,
    SimSystem::Flying,
    SimSystem::FlyingSequential,
];

fn llama() -> CostModel {
    CostModel::new(HwSpec::default(), PaperModel::llama70b())
}

// ---------------------------------------------------------------------------
// Randomized property tests
// ---------------------------------------------------------------------------

#[test]
fn prop_equivalent_on_random_bursty_traces() {
    let cm = llama();
    prop_check("event core ≡ reference on bursty traces", 12, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 200));
        wl.phase_secs = g.f64(5.0, 30.0);
        wl.high_rate = (g.f64(5.0, 15.0), g.f64(15.0, 40.0));
        let trace = generate(&wl);
        let sys = *g.choose(&ALL_SYSTEMS);
        check_equivalent(sys, &cm, &trace, &SimConfig::default())
    });
}

#[test]
fn prop_equivalent_on_priority_and_long_context_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("event core ≡ reference on priority/long traces", 12, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.4);
        wl.long_frac = g.f64(0.05, 0.25);
        // Long requests straddle the single-engine KV capacity so the
        // memory-driven TP path (Use Case 3) and rejections both trigger.
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let mut trace = generate(&wl);
        // Sprinkle explicit TP demands (latency-strict clients).
        for r in trace.iter_mut() {
            if r.id % 17 == 0 {
                r.tp_demand = Some(*g.choose(&[2usize, 4]));
            }
        }
        let sys = *g.choose(&ALL_SYSTEMS);
        check_equivalent(sys, &cm, &trace, &SimConfig::default())
    });
}

#[test]
fn prop_equivalent_across_models_and_configs() {
    prop_check("event core ≡ reference across models/configs", 8, |g| {
        let model = match g.usize(0, 2) {
            0 => PaperModel::llama70b(),
            1 => PaperModel::gptoss120b(),
            _ => PaperModel::nemotron8b(),
        };
        let cm = CostModel::new(HwSpec::default(), model);
        let cfg = SimConfig {
            chunk_tokens: *g.choose(&[512usize, 2048, 4096]),
            max_batch: *g.choose(&[8usize, 48]),
            ..SimConfig::default()
        };
        let wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 120));
        let trace = generate(&wl);
        let sys = *g.choose(&ALL_SYSTEMS);
        check_equivalent(sys, &cm, &trace, &cfg)
    });
}

// ---------------------------------------------------------------------------
// Bench-scenario equivalence (scaled-down fig8/fig9/fig10/table1/table2)
// ---------------------------------------------------------------------------

#[test]
fn fig8_fig9_scenario_equivalence() {
    // fig8 and fig9 share the saturation-scaled bursty workload.
    for model in [PaperModel::llama70b(), PaperModel::gptoss120b(), PaperModel::nemotron8b()] {
        let skip_shift = model.name.contains("GPT-OSS");
        let cm = CostModel::new(HwSpec::default(), model);
        let mut wl = WorkloadCfg::paper_full(4242, 300);
        let sat = cm.tp_saturation_rps(2064, 288);
        wl.low_rate = (0.12 * sat, 0.30 * sat);
        wl.high_rate = (0.60 * sat, 1.20 * sat);
        let trace = generate(&wl);
        for sys in [
            SimSystem::StaticDp,
            SimSystem::StaticTp(8),
            SimSystem::Shift,
            SimSystem::Flying,
        ] {
            if skip_shift && sys == SimSystem::Shift {
                continue;
            }
            assert_equivalent(sys, &cm, &trace, &SimConfig::default());
        }
    }
}

#[test]
fn fig10_long_context_scenario_equivalence() {
    for (model, ctx) in [
        (PaperModel::llama70b(), 8_192usize),
        (PaperModel::gptoss120b(), 131_072),
        (PaperModel::nemotron8b(), 1_000_000),
    ] {
        let cm = CostModel::new(HwSpec::default(), model);
        let gap = cm.prefill_s(ctx, cm.hw.n_gpus) * 1.05;
        let trace: Vec<Request> = (0..12u64)
            .map(|i| Request {
                id: i,
                arrival: i as f64 * gap,
                prompt_len: ctx,
                output_len: 64,
                priority: Priority::Normal,
                tp_demand: None,
                prefix_family: None,
            })
            .collect();
        for sys in [SimSystem::StaticDp, SimSystem::StaticTp(8), SimSystem::Flying] {
            assert_equivalent(sys, &cm, &trace, &SimConfig::default());
        }
    }
}

#[test]
fn table1_priority_scenario_equivalence() {
    let cm = llama();
    let mut wl = WorkloadCfg::paper_full(77, 300);
    wl.low_rate = (3.0, 5.0);
    wl.high_rate = (3.0, 5.0);
    wl.priority_frac = 0.10;
    let trace = generate(&wl);
    for sys in [SimSystem::StaticTp(8), SimSystem::StaticDp, SimSystem::Flying] {
        assert_equivalent(sys, &cm, &trace, &SimConfig::default());
    }
}

#[test]
fn table2_switching_scenario_equivalence() {
    // Table 2's sim half only reads the cost model, but its switching
    // behavior is the Flying TP-demand path — exercise it explicitly.
    let cm = llama();
    let trace: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.4,
            prompt_len: 512,
            output_len: 32,
            priority: Priority::Normal,
            tp_demand: if i % 3 == 0 { Some(2) } else { None },
            prefix_family: None,
        })
        .collect();
    for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
        assert_equivalent(sys, &cm, &trace, &SimConfig::default());
    }
}

// ---------------------------------------------------------------------------
// Control-plane no-op equivalence: with StaticController::hold() the
// ControlRuntime threaded through the event core must not perturb a single
// decision — outcomes must match both the plain event core AND the loop
// reference, on the property traces and on every scenario-library workload.
// ---------------------------------------------------------------------------

fn check_adaptive_hold_equivalent(
    cm: &CostModel,
    trace: &[Request],
    cfg: &SimConfig,
) -> Result<(), String> {
    let mut rt = ControlRuntime::new(
        Box::new(StaticController::hold()),
        ControlConfig::default(),
    );
    let adaptive = simulate_adaptive(cm, trace, cfg, &mut rt);
    if rt.plan_changes() != 0 {
        return Err(format!("hold controller changed plans ({})", rt.plan_changes()));
    }
    let event = simulate(SimSystem::Flying, cm, trace, cfg);
    outcomes_equivalent(&adaptive, &event).map_err(|e| format!("adaptive-hold vs event: {e}"))?;
    let reference = simulate_reference(SimSystem::Flying, cm, trace, cfg);
    outcomes_equivalent(&adaptive, &reference)
        .map_err(|e| format!("adaptive-hold vs reference: {e}"))
}

#[test]
fn prop_adaptive_hold_equivalent_on_random_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("adaptive(hold) ≡ reference on random traces", 10, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.3);
        wl.long_frac = g.f64(0.0, 0.2);
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let trace = generate(&wl);
        check_adaptive_hold_equivalent(&cm, &trace, &SimConfig::default())
    });
}

#[test]
fn adaptive_hold_equivalent_on_every_scenario() {
    let cm = llama();
    for scenario in Scenario::ALL {
        let trace = scenario.generate(11, 150);
        if let Err(e) = check_adaptive_hold_equivalent(&cm, &trace, &SimConfig::default()) {
            panic!("{scenario}: {e}");
        }
    }
}

// ---------------------------------------------------------------------------
// Switch-backfill differential guarantees (ISSUE 3): with
// `switch_backfill = false` (explicitly, not just by default) the event
// core must stay byte-identical to the loop reference on every
// scenario-library workload and on randomized traces; with it on, the
// transition path may legitimately re-time work but must keep every
// request terminal and never *add* stall to a merge window.
// ---------------------------------------------------------------------------

#[test]
fn backfill_off_is_byte_identical_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { switch_backfill: false, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let trace = scenario.generate(23, 150);
        for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
            if let Err(e) = check_equivalent(sys, &cm, &trace, &cfg) {
                panic!("{scenario}: {e}");
            }
        }
    }
}

#[test]
fn prop_backfill_off_is_byte_identical_on_random_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("backfill-off ≡ reference", 10, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.4);
        wl.long_frac = g.f64(0.0, 0.2);
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let trace = generate(&wl);
        let cfg = SimConfig { switch_backfill: false, ..SimConfig::default() };
        check_equivalent(*g.choose(&ALL_SYSTEMS), &cm, &trace, &cfg)
    });
}

#[test]
fn backfill_on_keeps_every_request_terminal_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { switch_backfill: true, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let n = 150;
        let trace = scenario.generate(23, n);
        let on = simulate(SimSystem::Flying, &cm, &trace, &cfg);
        // Finish records cover completions AND rejections: nothing may be
        // stranded in a shell or a forming group.
        assert_eq!(
            on.recorder.summary(None).finished,
            n,
            "{scenario}: lost requests under backfill"
        );
        assert!(
            on.switch_stall_s >= -1e-9,
            "{scenario}: backfill credited more work than the window held"
        );
    }
}

// ---------------------------------------------------------------------------
// KV-migration differential guarantees (ISSUE 4): with
// `switch_migrate = false` (explicitly, not just by default) the event core
// must stay byte-identical to the loop reference on every scenario-library
// workload — all eight, including switch_churn — and on randomized traces;
// with it on, every request stays terminal and live KV measurably crosses
// the DP↔TP boundary on the switch-heavy scenarios.
// ---------------------------------------------------------------------------

#[test]
fn migrate_off_is_byte_identical_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { switch_migrate: false, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let trace = scenario.generate(29, 150);
        for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
            if let Err(e) = check_equivalent(sys, &cm, &trace, &cfg) {
                panic!("{scenario}: {e}");
            }
        }
    }
}

#[test]
fn prop_migrate_off_is_byte_identical_on_random_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("migrate-off ≡ reference", 10, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.4);
        wl.long_frac = g.f64(0.0, 0.2);
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let mut trace = generate(&wl);
        // Explicit TP demands exercise the merge path the migrate flag
        // gates; with the flag off they must not perturb a single decision.
        for r in trace.iter_mut() {
            if r.id % 13 == 0 {
                r.tp_demand = Some(*g.choose(&[2usize, 4]));
            }
        }
        let cfg = SimConfig { switch_migrate: false, ..SimConfig::default() };
        check_equivalent(*g.choose(&ALL_SYSTEMS), &cm, &trace, &cfg)
    });
}

#[test]
fn migrate_on_keeps_every_request_terminal_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { switch_migrate: true, ..SimConfig::default() };
    let mut any_carried = false;
    for scenario in Scenario::ALL {
        let n = 150;
        let trace = scenario.generate(23, n);
        let on = simulate(SimSystem::Flying, &cm, &trace, &cfg);
        assert_eq!(
            on.recorder.summary(None).finished,
            n,
            "{scenario}: lost requests under migration"
        );
        any_carried |= on.recompute_tokens_avoided > 0;
    }
    assert!(any_carried, "no scenario carried KV across a flip");
}

#[test]
fn migrate_on_carries_live_kv_on_switch_churn() {
    // switch_churn is built so merges land on busy decode residents: live
    // KV must cross the layout boundary, and the carried token count is
    // deterministic per seed.
    let cm = llama();
    let trace = Scenario::SwitchChurn.generate(7, 250);
    let on_cfg = SimConfig { switch_migrate: true, ..SimConfig::default() };
    let a = simulate(SimSystem::Flying, &cm, &trace, &on_cfg);
    assert!(a.recompute_tokens_avoided > 0);
    let b = simulate(SimSystem::Flying, &cm, &trace, &on_cfg);
    assert_eq!(a.recompute_tokens_avoided, b.recompute_tokens_avoided);
    let off = simulate(SimSystem::Flying, &cm, &trace, &SimConfig::default());
    assert_eq!(off.recompute_tokens_avoided, 0);
}

// ---------------------------------------------------------------------------
// Step-pipeline overlap differential guarantees (ISSUE 9): with
// `overlap = false` (explicitly, not just by default) the event core must
// stay byte-identical to the loop reference on every scenario-library
// workload — all eight — and on randomized traces; with it on, every
// request stays terminal, the journal shows a measurable overlap window on
// the switch-heavy scenario, and the stall-attribution identity still
// reconstructs the aggregate exactly.
// ---------------------------------------------------------------------------

#[test]
fn overlap_off_is_byte_identical_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { overlap: false, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let trace = scenario.generate(31, 150);
        for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
            if let Err(e) = check_equivalent(sys, &cm, &trace, &cfg) {
                panic!("{scenario}: {e}");
            }
        }
    }
}

#[test]
fn prop_overlap_off_is_byte_identical_on_random_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("overlap-off ≡ reference", 10, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.4);
        wl.long_frac = g.f64(0.0, 0.2);
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let mut trace = generate(&wl);
        // Explicit TP demands exercise the merge path whose migration
        // charge the overlap flag re-times; off, not a single decision may
        // move.
        for r in trace.iter_mut() {
            if r.id % 13 == 0 {
                r.tp_demand = Some(*g.choose(&[2usize, 4]));
            }
        }
        let cfg = SimConfig { overlap: false, ..SimConfig::default() };
        check_equivalent(*g.choose(&ALL_SYSTEMS), &cm, &trace, &cfg)
    });
}

#[test]
fn overlap_on_keeps_every_request_terminal_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { overlap: true, switch_migrate: true, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let n = 150;
        let trace = scenario.generate(31, n);
        let on = simulate(SimSystem::Flying, &cm, &trace, &cfg);
        assert_eq!(
            on.recorder.summary(None).finished,
            n,
            "{scenario}: lost requests under overlap"
        );
        // The identity the bench hard-gates, asserted here with the new
        // credit term live: components must reconstruct the aggregate.
        assert!(
            (on.stall.total() - on.switch_stall_s).abs() <= 1e-9,
            "{scenario}: stall attribution broke under overlap \
             (total {} vs aggregate {})",
            on.stall.total(),
            on.switch_stall_s
        );
    }
}

#[test]
fn overlap_on_hides_migration_inside_the_drain_window_on_switch_churn() {
    // switch_churn lands merges on busy decode residents, so migration
    // charges are guaranteed; with overlap on they must (partially) hide
    // inside the drain window — journal-verified, and visible as reduced
    // aggregate stall at equal migration component.
    let cm = llama();
    let trace = Scenario::SwitchChurn.generate(7, 250);
    let off = SimConfig { switch_migrate: true, trace: true, ..SimConfig::default() };
    let on = SimConfig { overlap: true, ..off.clone() };
    let a = simulate(SimSystem::Flying, &cm, &trace, &off);
    let b = simulate(SimSystem::Flying, &cm, &trace, &on);
    // Same migrations ran (the overlap flag re-times, never re-decides)...
    assert_eq!(a.recompute_tokens_avoided, b.recompute_tokens_avoided);
    assert!(a.recompute_tokens_avoided > 0);
    assert!((a.stall.migration_s - b.stall.migration_s).abs() <= 1e-9);
    // ...but the window credit is real and only exists with the flag on.
    assert_eq!(a.stall.pipeline_overlap_s, 0.0);
    assert!(b.stall.pipeline_overlap_s > 0.0, "no overlap window credited");
    assert!(b.switch_stall_s < a.switch_stall_s - 1e-9, "stall did not drop");
    // Journal: every async transfer window is recorded, and at least one
    // completion actually overlapped.
    let journal = b.journal.as_ref().expect("trace on");
    let begins = journal.iter().filter(|(_, e)| e.kind() == "async_migrate_begin").count();
    let ends: Vec<f64> = journal
        .iter()
        .filter_map(|&(_, e)| match e {
            flying_serving::obs::Event::AsyncMigrateEnd { overlapped_s, .. } => Some(overlapped_s),
            _ => None,
        })
        .collect();
    assert_eq!(begins, ends.len());
    assert!(begins > 0, "no async transfers journaled");
    assert!(ends.iter().any(|&s| s > 0.0), "no transfer overlapped its window");
    // Off-journal stays clean of the new kinds.
    let off_journal = a.journal.as_ref().expect("trace on");
    assert!(off_journal.iter().all(|(_, e)| !e.kind().starts_with("async_migrate")));
    assert!(off_journal.iter().all(|(_, e)| !e.kind().starts_with("slot_")));
}

// ---------------------------------------------------------------------------
// Prefix-cache differential guarantees (ISSUE 10): with
// `prefix_cache = false` (explicitly, not just by default) the event core
// must stay byte-identical to the loop reference on every scenario-library
// workload — all eight, including shared_prefix, whose traces carry family
// tags the unarmed cache must ignore — and on randomized traces; with it
// on, every request stays terminal, emitted work is unchanged, and the
// cache measurably adopts prompt tokens on the shared-prefix scenario.
// ---------------------------------------------------------------------------

#[test]
fn prefix_cache_off_is_byte_identical_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { prefix_cache: false, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let trace = scenario.generate(37, 150);
        for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
            if let Err(e) = check_equivalent(sys, &cm, &trace, &cfg) {
                panic!("{scenario}: {e}");
            }
        }
    }
}

#[test]
fn prop_prefix_cache_off_is_byte_identical_on_random_traces() {
    let cm = llama();
    let dp_cap = cm.kv_capacity_tokens(cm.model.min_gpus);
    prop_check("prefix-off ≡ reference", 10, |g| {
        let mut wl = WorkloadCfg::paper_full(g.u64(0, 1 << 30), g.usize(40, 160));
        wl.priority_frac = g.f64(0.0, 0.4);
        wl.long_frac = g.f64(0.0, 0.2);
        wl.long_ctx_range = (dp_cap / 2, dp_cap * 3);
        let mut trace = generate(&wl);
        // Tag a slice of the trace with shared families: with the flag off
        // the tags must not perturb a single decision.
        for r in trace.iter_mut() {
            if r.id % 5 == 0 {
                r.prefix_family = Some((r.id % 3, r.prompt_len / 2));
            }
        }
        let cfg = SimConfig { prefix_cache: false, ..SimConfig::default() };
        check_equivalent(*g.choose(&ALL_SYSTEMS), &cm, &trace, &cfg)
    });
}

#[test]
fn prefix_cache_on_keeps_every_request_terminal_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { prefix_cache: true, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let n = 150;
        let trace = scenario.generate(37, n);
        let on = simulate(SimSystem::Flying, &cm, &trace, &cfg);
        assert_eq!(
            on.recorder.summary(None).finished,
            n,
            "{scenario}: lost requests under prefix cache"
        );
    }
}

#[test]
fn prefix_cache_on_adopts_tokens_on_shared_prefix() {
    // shared_prefix clusters 80% of requests into six families; after each
    // family's first admission, later members must skip their cached
    // prefix.  The adopted count is deterministic per seed, and the off
    // run reports zero.
    let cm = llama();
    let trace = Scenario::SharedPrefix.generate(7, 250);
    let on_cfg = SimConfig { prefix_cache: true, ..SimConfig::default() };
    let a = simulate(SimSystem::Flying, &cm, &trace, &on_cfg);
    assert!(a.prefill_tokens_avoided > 0, "no prompt tokens adopted");
    let b = simulate(SimSystem::Flying, &cm, &trace, &on_cfg);
    assert_eq!(a.prefill_tokens_avoided, b.prefill_tokens_avoided);
    let off = simulate(SimSystem::Flying, &cm, &trace, &SimConfig::default());
    assert_eq!(off.prefill_tokens_avoided, 0);
    // Adoption only ever skips prefill compute — every request still
    // finishes, with the same completion count as the off run.
    assert_eq!(
        a.recorder.summary(None).finished,
        off.recorder.summary(None).finished
    );
}

#[test]
fn stall_semantics_match_reference() {
    // Both implementations must resolve the blocked-idle stall by
    // rejecting the same request set (the seed would have spun forever).
    let cm = llama();
    let trace = generate(&WorkloadCfg::paper_full(9, 10));
    let cfg = SimConfig { max_batch: 0, ..SimConfig::default() };
    assert_equivalent(SimSystem::StaticDp, &cm, &trace, &cfg);
    let o = simulate(SimSystem::StaticDp, &cm, &trace, &cfg);
    assert_eq!(o.rejected.len(), 10);
}
