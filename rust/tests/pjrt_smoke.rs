//! PJRT behavior probes: output untupling and buffer chaining via execute_b.
use xla::{HloModuleProto, Literal, PjRtClient, XlaComputation};

// Tracking: requires a PJRT CPU plugin plus a hand-built /tmp/tuple_test.hlo.txt
// probe artifact; neither exists in CI.  Run locally with
// `cargo test --features pjrt -- --ignored` after `make artifacts`.
#[test]
#[ignore = "requires PJRT CPU plugin and local probe artifact"]
fn tuple_outputs_and_buffer_chaining() -> anyhow::Result<()> {
    let client = PjRtClient::cpu()?;
    let proto = HloModuleProto::from_text_file("/tmp/tuple_test.hlo.txt")?;
    let exe = client.compile(&XlaComputation::from_proto(&proto))?;
    let x = Literal::vec1(&[1f32, 2., 3., 4.]);
    let y = Literal::vec1(&[10f32, 20., 30., 40.]);
    let out = exe.execute::<Literal>(&[x, y])?;
    println!("replicas={} outputs_per_replica={}", out.len(), out[0].len());
    if out[0].len() == 3 {
        let a = out[0][0].to_literal_sync()?.to_vec::<f32>()?;
        println!("untupled! out0={a:?}");
        // chain: feed output buffers back via execute_b
        let xb = client.buffer_from_host_buffer(&[5f32, 6., 7., 8.], &[4], None)?;
        let out2 = exe.execute_b(&[&xb, &out[0][2]])?;
        let b = out2[0][0].to_literal_sync()?.to_vec::<f32>()?;
        println!("chained out0={b:?}");
        assert_eq!(b, vec![6., 7., 8., 9.]);
    } else {
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        println!("single tuple buffer with {} parts", parts.len());
    }
    Ok(())
}
