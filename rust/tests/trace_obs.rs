//! Flight-recorder differential guarantees (ISSUE 7).
//!
//! `--trace` is an observability flag, so it gets the same discipline as
//! `switch_backfill` / `switch_migrate` / `watchdog` before it:
//!   * off (the default, asserted explicitly) the event core stays
//!     byte-identical to the preserved loop reference on every
//!     scenario-library workload;
//!   * on, it may allocate its ring up front but must not perturb a single
//!     outcome — completions, rejections, switch counts, stall seconds and
//!     every recorded token timestamp must match the untraced run exactly;
//!   * the stall-attribution components must reconstruct `switch_stall_s`
//!     within 1e-9 on every scenario × flag combination (the bench
//!     hard-gates `priority_storm` and `switch_churn`).

use flying_serving::control::{ControlConfig, ControlRuntime, ThresholdController};
use flying_serving::json::Value;
use flying_serving::sim::{
    outcomes_equivalent, simulate, simulate_adaptive, simulate_reference, CostModel, HwSpec,
    PaperModel, SimConfig, SimSystem,
};
use flying_serving::workload::Scenario;

fn llama() -> CostModel {
    CostModel::new(HwSpec::default(), PaperModel::llama70b())
}

#[test]
fn trace_off_is_byte_identical_on_every_scenario() {
    let cm = llama();
    let cfg = SimConfig { trace: false, ..SimConfig::default() };
    for scenario in Scenario::ALL {
        let trace = scenario.generate(31, 150);
        for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
            let a = simulate(sys, &cm, &trace, &cfg);
            assert!(a.journal.is_none(), "{scenario}: journal allocated with trace off");
            let b = simulate_reference(sys, &cm, &trace, &cfg);
            if let Err(e) = outcomes_equivalent(&a, &b) {
                panic!("{scenario}/{}: {e}", sys.label());
            }
        }
    }
}

#[test]
fn trace_on_does_not_perturb_outcomes() {
    // The journal observes; it must never steer.  Compare an armed run to
    // an untraced run on exact values, including the timing-derived fields
    // `outcomes_equivalent` deliberately ignores.
    let cm = llama();
    for scenario in Scenario::ALL {
        let trace = scenario.generate(31, 150);
        for (backfill, migrate) in [(false, false), (true, false), (false, true), (true, true)] {
            let base = SimConfig {
                switch_backfill: backfill,
                switch_migrate: migrate,
                ..SimConfig::default()
            };
            let off = simulate(SimSystem::Flying, &cm, &trace, &base);
            let on_cfg = SimConfig { trace: true, ..base };
            let on = simulate(SimSystem::Flying, &cm, &trace, &on_cfg);
            let tag = format!("{scenario} backfill={backfill} migrate={migrate}");
            outcomes_equivalent(&off, &on).unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(off.switch_stall_s.to_bits(), on.switch_stall_s.to_bits(), "{tag}: stall");
            assert_eq!(off.stall, on.stall, "{tag}: stall breakdown");
            assert_eq!(
                off.recompute_tokens_avoided, on.recompute_tokens_avoided,
                "{tag}: kv carried"
            );
            assert_eq!(off.n_switches, on.n_switches, "{tag}: switches");
            assert!(on.journal.is_some(), "{tag}: no journal from a traced run");
            for ((rid_a, a), (rid_b, b)) in off.recorder.records().zip(on.recorder.records()) {
                assert_eq!(rid_a, rid_b, "{tag}: record order");
                assert_eq!(a.token_times, b.token_times, "{tag}: rid {rid_a} token times");
                assert_eq!(a.finished, b.finished, "{tag}: rid {rid_a} finish");
            }
        }
    }
}

#[test]
fn stall_components_sum_to_aggregate_on_every_scenario() {
    let cm = llama();
    for scenario in Scenario::ALL {
        let trace = scenario.generate(31, 200);
        for (backfill, migrate) in [(false, false), (true, false), (false, true), (true, true)] {
            let cfg = SimConfig {
                switch_backfill: backfill,
                switch_migrate: migrate,
                ..SimConfig::default()
            };
            for sys in [SimSystem::Flying, SimSystem::FlyingSequential] {
                let o = simulate(sys, &cm, &trace, &cfg);
                let err = (o.stall.total() - o.switch_stall_s).abs();
                assert!(
                    err < 1e-9,
                    "{scenario}/{} backfill={backfill} migrate={migrate}: \
                     components {} vs aggregate {} (err {err:e})",
                    sys.label(),
                    o.stall.total(),
                    o.switch_stall_s
                );
            }
        }
    }
}

#[test]
fn journal_captures_switch_lifecycle_and_roundtrips() {
    // switch_churn forces frequent DP↔TP flips, so an armed journal must
    // see the full lifecycle, and its JSONL dump must parse back through
    // the same code path the CI smoke step uses.
    let cm = llama();
    let trace = Scenario::SwitchChurn.generate(7, 250);
    let cfg = SimConfig {
        trace: true,
        switch_backfill: true,
        switch_migrate: true,
        ..SimConfig::default()
    };
    let o = simulate(SimSystem::Flying, &cm, &trace, &cfg);
    let j = o.journal.as_ref().expect("traced run must surface its journal");
    assert!(!j.is_empty());
    let counts = j.counts();
    assert!(counts.get("drain_begin").copied().unwrap_or(0) > 0, "{counts:?}");
    assert!(counts.get("promote").copied().unwrap_or(0) > 0, "{counts:?}");
    assert!(counts.get("exec").copied().unwrap_or(0) > 0, "{counts:?}");

    let mut buf = Vec::new();
    let meta = Value::obj(vec![
        ("scenario", Value::str("switch_churn")),
        ("stall", o.stall.to_value()),
    ]);
    j.write_jsonl(&mut buf, Some(&meta)).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let s = flying_serving::obs::summarize_jsonl(&text).unwrap();
    assert_eq!(s.meta_lines, 1);
    assert_eq!(s.events, j.len());
    assert_eq!(
        s.by_kind.get("promote").copied().unwrap_or(0),
        counts.get("promote").copied().unwrap_or(0)
    );
    // Events are drained oldest-first with nondecreasing-ish clocks; the
    // time range must at least be ordered and finite.
    assert!(s.t_min.is_finite() && s.t_max.is_finite() && s.t_min <= s.t_max);
}

#[test]
fn journal_derives_timelines() {
    let cm = llama();
    let trace = Scenario::SwitchChurn.generate(7, 250);
    let cfg = SimConfig { trace: true, ..SimConfig::default() };
    let o = simulate(SimSystem::Flying, &cm, &trace, &cfg);
    let j = o.journal.as_ref().unwrap();
    let n_units = cm.hw.n_gpus / cm.model.min_gpus;
    let tl = j.mode_timeline(n_units);
    assert_eq!(tl.len(), n_units);
    // switch_churn promotes at least one group, so some engine changes mode.
    assert!(tl.iter().any(|t| !t.is_empty()), "no mode transitions recorded");
    // Promotions must reach a width > 1 somewhere in the timeline.
    assert!(
        tl.iter().flatten().any(|&(_, w)| w > 1),
        "no TP-width entry in any timeline"
    );
    let util = j.utilization(n_units, 5.0);
    let busy: f64 = util.iter().flatten().sum();
    assert!(busy > 0.0, "exec events produced no utilization");
}

#[test]
fn adaptive_trace_records_control_ticks() {
    let cm = llama();
    let trace = Scenario::Diurnal.generate(11, 200);
    let cfg = SimConfig { trace: true, ..SimConfig::default() };
    let mut rt = ControlRuntime::new(
        Box::new(ThresholdController::default()),
        ControlConfig::default(),
    );
    let o = simulate_adaptive(&cm, &trace, &cfg, &mut rt);
    let j = o.journal.as_ref().unwrap();
    let n_ticks = j.counts().get("ctrl_tick").copied().unwrap_or(0);
    assert!(n_ticks > 0, "adaptive run journaled no control ticks");
    // Every tick line must carry the full telemetry/plan payload.
    let mut buf = Vec::new();
    j.write_jsonl(&mut buf, None).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let mut seen = 0;
    for line in text.lines() {
        let v = Value::parse(line).unwrap();
        if v.str_field("ev").map(|k| k == "ctrl_tick").unwrap_or(false) {
            seen += 1;
            assert!(v.get("arrival_rate").is_some());
            assert!(v.get("desired").is_some());
            assert!(v.get("adopted").is_some());
            assert!(v.get("rejected_reason").is_some());
        }
    }
    assert_eq!(seen, n_ticks);
}
