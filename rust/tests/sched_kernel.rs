//! Decision-trace differential for the scheduling kernel (ISSUE 5): drive
//! the *same* `SchedEvent` stream through two differently-shaped drivers —
//! one accounting engine capacity in KV tokens (the simulator's shape), one
//! in fixed-size blocks (the coordinator's admission-control shape) — and
//! assert the kernel's emitted `SchedAction` sequences are byte-identical,
//! across every scenario-library workload (all seven) plus randomized
//! traces.
//!
//! The drivers share nothing but the kernel: each keeps its own occupancy
//! table in its own unit.  With the per-engine capacity a whole number of
//! blocks and request sizes block-aligned, the two accountings are exactly
//! equivalent — so any divergence in the recorded placements would mean the
//! kernel's walk order, backlog math, constraint tiers, or tie-breaks
//! depend on the driver, which is precisely what the unified kernel exists
//! to make impossible.  (Group residency is abstracted here — TP
//! placements are recorded but occupy no capacity; the full lifecycle
//! equivalence is covered by `tests/sim_equivalence.rs` and the stub
//! cluster suite.)

use flying_serving::coordinator::policy::{FlyingPolicy, ModeDecision, Policy, Snapshot};
use flying_serving::sched::{Kernel, LeastLoaded, Placement, SchedAction, SchedEvent};
use flying_serving::util::prop::prop_check;
use flying_serving::workload::{Priority, Scenario};

const BLOCK: usize = 512;

/// One request as the event stream carries it (sizes pre-snapped to whole
/// blocks so token- and block-accounting agree exactly).
#[derive(Clone, Copy, Debug)]
struct EvReq {
    rid: u64,
    prompt: usize,
    output: usize,
    priority: Priority,
    tp_demand: Option<usize>,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(EvReq),
    /// Oldest bound request completes, freeing its engine capacity.
    Complete,
}

/// How a driver accounts per-engine capacity.  The two implementations are
/// numerically equivalent (cap is block-aligned, requests are snapped), but
/// the arithmetic — and therefore any accidental driver dependence — is
/// entirely theirs.
trait CapModel {
    /// Capacity advertised to the policy snapshot, in tokens (both shapes
    /// report tokens, as the real paths do).
    fn dp_capacity_tokens(&self) -> usize;
    /// Occupancy charged for a request of `total` tokens, in driver units.
    fn occupy(&self, total: usize) -> u64;
    /// Whether `total` more tokens fit an engine at `used` driver units.
    fn fits(&self, used: u64, total: usize) -> bool;
}

/// Simulator-shaped: Σ tokens against a token capacity.
struct TokenCap {
    cap_tokens: usize,
}

impl CapModel for TokenCap {
    fn dp_capacity_tokens(&self) -> usize {
        self.cap_tokens
    }
    fn occupy(&self, total: usize) -> u64 {
        total as u64
    }
    fn fits(&self, used: u64, total: usize) -> bool {
        used + total as u64 <= self.cap_tokens as u64
    }
}

/// Coordinator-shaped: ceil-divided blocks against a block-pool capacity.
struct BlockCap {
    cap_blocks: usize,
}

impl CapModel for BlockCap {
    fn dp_capacity_tokens(&self) -> usize {
        self.cap_blocks * BLOCK
    }
    fn occupy(&self, total: usize) -> u64 {
        (total.div_ceil(BLOCK)) as u64
    }
    fn fits(&self, used: u64, total: usize) -> bool {
        used + total.div_ceil(BLOCK) as u64 <= self.cap_blocks as u64
    }
}

/// Drive the kernel over the event stream with the given capacity shape and
/// return the recorded decision trace.
fn drive<C: CapModel>(events: &[Ev], cap: &C, n_engines: usize) -> Vec<SchedAction> {
    let mut kernel: Kernel<u32> = Kernel::new();
    kernel.enable_trace();
    for e in 0..n_engines {
        kernel.index.refresh_engine(e, true, true);
    }
    let mut policy = FlyingPolicy::default();
    let mut reqs: Vec<EvReq> = Vec::new();
    let mut used: Vec<u64> = vec![0; n_engines];
    let mut load: Vec<usize> = vec![0; n_engines];
    // (engine, occupancy) of bound requests, oldest first.
    let mut bound: std::collections::VecDeque<(usize, u64)> = std::collections::VecDeque::new();

    for ev in events {
        match *ev {
            Ev::Arrive(r) => {
                reqs.push(r);
                kernel.on_event(SchedEvent::Arrival {
                    h: (reqs.len() - 1) as u32,
                    priority: r.priority,
                });
            }
            Ev::Complete => {
                if let Some((e, occ)) = bound.pop_front() {
                    used[e] -= occ;
                    load[e] -= 1;
                    if load[e] == 0 {
                        kernel.index.refresh_engine(e, true, true);
                    }
                    kernel.on_event(SchedEvent::StepComplete);
                }
            }
        }
        if !kernel.should_walk() {
            continue;
        }
        let mut walk = kernel.begin_walk();
        while let Some((h, high)) = walk.next() {
            let r = reqs[h as usize];
            let snap = Snapshot {
                now: 0.0,
                queue_len: walk.backlog_now(),
                idle_engines: kernel.index.idle_count(),
                n_engines,
                dp_capacity_tokens: cap.dp_capacity_tokens(),
                max_tp: n_engines,
                kv_frac: 0.0,
            };
            let total = r.prompt + r.output;
            let placement =
                match policy.decide_for(r.rid, r.prompt, r.output, r.priority, r.tp_demand, &snap)
                {
                    ModeDecision::Reject => Placement::Reject,
                    ModeDecision::Tp(p) => Placement::Tp { width: p.min(n_engines) as u32 },
                    ModeDecision::Dp => {
                        let mut ll = LeastLoaded::new();
                        let mut cands = kernel.index.dp_candidates();
                        while cands != 0 {
                            let e = cands.trailing_zeros() as usize;
                            cands &= cands - 1;
                            if cap.fits(used[e], total) {
                                ll.offer(e, load[e]);
                            }
                        }
                        match ll.pick() {
                            Some(e) => {
                                used[e] += cap.occupy(total);
                                load[e] += 1;
                                kernel.index.refresh_engine(e, true, false);
                                bound.push_back((e, cap.occupy(total)));
                                Placement::Dp { unit: e as u32, backfill: false }
                            }
                            None => Placement::Defer,
                        }
                    }
                };
            walk.settle(h, high, r.rid, placement);
        }
        kernel.end_walk(walk);
    }
    kernel.take_trace()
}

/// Snap a size up to a whole number of blocks (≥ one block) so token and
/// block occupancy are exactly equivalent.
fn snap(tokens: usize) -> usize {
    tokens.div_ceil(BLOCK).max(1) * BLOCK
}

/// Build the shared event stream from a workload trace: arrivals in time
/// order, with a completion injected every third arrival so capacity churns
/// and deferred requests get re-walked.
fn stream_from(trace: &[flying_serving::workload::Request]) -> Vec<Ev> {
    let mut events = Vec::with_capacity(trace.len() * 2);
    for (i, r) in trace.iter().enumerate() {
        events.push(Ev::Arrive(EvReq {
            rid: r.id,
            prompt: snap(r.prompt_len),
            output: snap(r.output_len),
            priority: r.priority,
            tp_demand: r.tp_demand,
        }));
        if i % 3 == 2 {
            events.push(Ev::Complete);
        }
    }
    // Drain: completions keep dirtying the walk until nothing is bound.
    for _ in 0..trace.len() {
        events.push(Ev::Complete);
    }
    events
}

#[test]
fn decision_traces_identical_across_driver_shapes_on_every_scenario() {
    let n_engines = 4;
    let cap_blocks = 400; // 204_800 tokens — long-context straddles it
    for scenario in Scenario::ALL {
        let trace = scenario.generate(17, 250);
        let events = stream_from(&trace);
        let tokens = drive(&events, &TokenCap { cap_tokens: cap_blocks * BLOCK }, n_engines);
        let blocks = drive(&events, &BlockCap { cap_blocks }, n_engines);
        assert!(!tokens.is_empty(), "{scenario}: no decisions recorded");
        assert_eq!(
            tokens, blocks,
            "{scenario}: kernel decisions diverged between driver shapes"
        );
        // Sanity: the stream must exercise more than one placement kind
        // somewhere across the scenario set (checked per scenario for the
        // rich ones below).
    }
}

#[test]
fn elastic_tiers_stream_exercises_every_placement_kind() {
    // The new 7th scenario is built so all three constraint tiers are live
    // at once: its trace must surface Dp, Tp, and Defer placements (Reject
    // appears on the long-context scenarios instead).
    let trace = Scenario::ElasticTiers.generate(17, 400);
    let events = stream_from(&trace);
    let actions = drive(&events, &BlockCap { cap_blocks: 40 }, 4);
    let has = |f: &dyn Fn(&Placement) -> bool| actions.iter().any(|a| f(&a.placement));
    assert!(has(&|p| matches!(p, Placement::Dp { .. })), "no DP placements");
    assert!(has(&|p| matches!(p, Placement::Tp { .. })), "no TP placements");
    assert!(has(&|p| matches!(p, Placement::Defer)), "no deferrals");
}

#[test]
fn prop_decision_traces_identical_on_random_streams() {
    prop_check("kernel trace ≡ across driver shapes", 24, |g| {
        let n_engines = *g.choose(&[2usize, 4, 8]);
        let cap_blocks = g.usize(8, 600);
        let n = g.usize(20, 160);
        let mut events = Vec::new();
        for rid in 0..n as u64 {
            let long = g.f64(0.0, 1.0) < 0.15;
            let prompt = if long {
                g.usize(cap_blocks * BLOCK / 2, cap_blocks * BLOCK * (n_engines + 1))
            } else {
                g.usize(1, 4000)
            };
            events.push(Ev::Arrive(EvReq {
                rid,
                prompt: snap(prompt),
                output: snap(g.usize(1, 512)),
                priority: if g.f64(0.0, 1.0) < 0.2 { Priority::High } else { Priority::Normal },
                tp_demand: if g.f64(0.0, 1.0) < 0.1 {
                    Some(*g.choose(&[2usize, 4]))
                } else {
                    None
                },
            }));
            if g.f64(0.0, 1.0) < 0.4 {
                events.push(Ev::Complete);
            }
        }
        for _ in 0..n {
            events.push(Ev::Complete);
        }
        let tokens = drive(&events, &TokenCap { cap_tokens: cap_blocks * BLOCK }, n_engines);
        let blocks = drive(&events, &BlockCap { cap_blocks }, n_engines);
        if tokens != blocks {
            return Err(format!(
                "traces diverged ({} vs {} actions)",
                tokens.len(),
                blocks.len()
            ));
        }
        Ok(())
    });
}
