//! End-to-end integration over the real PJRT engines: boots a thread
//! cluster on the llama-tiny artifacts and validates the full serving path
//! (chunked prefill -> paged decode -> mode switching) in every mode.
//!
//! The key invariant (proven against the jnp reference in
//! python/tests/test_model.py, re-proven here across the Rust+PJRT stack):
//! greedy decoding emits the *identical token sequence* under DP, TP-2, and
//! across live DP<->TP switches — switching is transparent to outputs.

use std::path::PathBuf;
use std::sync::Arc;

use flying_serving::baselines::{StaticDpPolicy, StaticTpPolicy};
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::workload::{synth_prompt_tokens, Priority};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Manifest::load(&dir).unwrap()))
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        id,
        prompt: synth_prompt_tokens(id, prompt_len),
        max_new,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    }
}

#[test]
fn dp_and_tp_emit_identical_tokens() {
    let Some(m) = manifest() else { return };

    // Serve the same two requests under static DP and static TP-2.
    let trace = vec![req(1, 19, 6), req(2, 40, 5)];

    let mut c1 = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let out_dp = c1
        .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c1.shutdown();

    let mut c2 = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let out_tp = c2
        .run_trace(trace, &mut StaticTpPolicy { p: 2 }, Strategy::Sequential)
        .unwrap();
    c2.shutdown();

    assert_eq!(out_dp.outputs.len(), 2);
    assert_eq!(out_dp.outputs[&1].len(), 6);
    assert_eq!(out_dp.outputs[&2].len(), 5);
    assert_eq!(out_dp.outputs, out_tp.outputs, "DP vs TP token mismatch");
    assert!(out_dp.rejected.is_empty() && out_tp.rejected.is_empty());
}

#[test]
fn deterministic_across_runs() {
    let Some(m) = manifest() else { return };
    let trace = vec![req(7, 25, 4)];
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut c = Cluster::start(&m, "llama-tiny", 1).unwrap();
        let o = c
            .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
            .unwrap();
        c.shutdown();
        outs.push(o.outputs);
    }
    assert_eq!(outs[0], outs[1]);
}

#[test]
fn flying_policy_switches_and_preserves_outputs() {
    let Some(m) = manifest() else { return };

    // Low load: flying should widen to TP; under a queued burst it should
    // run DP.  Either way outputs must match the static-DP ground truth.
    let mut trace = vec![];
    for i in 0..5u64 {
        let mut r = req(10 + i, 15 + 3 * i as usize, 4);
        r.arrival = 0.05 * i as f64;
        trace.push(r);
    }

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let truth = c
        .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let flying = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::HardPreempt)
        .unwrap();
    c.shutdown();

    assert_eq!(truth.outputs, flying.outputs);
    // The dynamic run must actually have exercised switching.
    assert!(
        !flying.switches.is_empty(),
        "flying policy never formed a TP group"
    );
    // Live switches are fast: well under 50ms each (paper: 15 ms vs 146+ s
    // cold start).
    for s in &flying.switches {
        assert!(s.latency_s < 0.05, "switch took {}s", s.latency_s);
    }
}

#[test]
fn long_context_served_by_flying_rejected_by_static_dp() {
    let Some(m) = manifest() else { return };
    let lm = m.model("llama-tiny").unwrap();
    let dp_cap = lm.cfg.dp_token_capacity();

    // A request that cannot fit a single engine's KV pool.
    let long = ServeRequest {
        id: 99,
        prompt: synth_prompt_tokens(99, dp_cap + 50),
        max_new: 3,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    };

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let dp = c
        .run_trace(vec![long.clone()], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(dp.rejected, vec![99], "static DP must OOM-reject");

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let fly = c
        .run_trace(vec![long], &mut FlyingPolicy::default(), Strategy::HardPreempt)
        .unwrap();
    c.shutdown();
    assert!(fly.rejected.is_empty(), "flying must serve via TP merge");
    assert_eq!(fly.outputs[&99].len(), 3);
}

#[test]
fn hard_preempt_priority_interrupts_and_resumes() {
    let Some(m) = manifest() else { return };

    // A normal request arrives first and starts decoding on DP; then a
    // high-priority request arrives and hard-preempts into a TP group.
    let mut background = req(1, 30, 8);
    background.arrival = 0.0;
    let mut priority = req(2, 12, 3);
    priority.priority = Priority::High;
    priority.arrival = 0.15;

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let out = c
        .run_trace(
            vec![background.clone(), priority.clone()],
            &mut FlyingPolicy::default(),
            Strategy::HardPreempt,
        )
        .unwrap();
    c.shutdown();

    // Both complete with full outputs (background resumed after preemption).
    assert_eq!(out.outputs[&1].len(), 8);
    assert_eq!(out.outputs[&2].len(), 3);

    // Background tokens match an undisturbed run (KV survived the pause).
    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let solo = c
        .run_trace(vec![background], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs[&1], solo.outputs[&1]);
}

#[test]
fn soft_preempt_speculative_tokens_consistent() {
    let Some(m) = manifest() else { return };

    let mut background = req(1, 30, 6);
    background.arrival = 0.0;
    let mut tp_req = req(2, 20, 5);
    tp_req.tp_demand = Some(2); // explicit TP demand triggers the bind path
    tp_req.arrival = 0.1;

    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let soft = c
        .run_trace(
            vec![background.clone(), tp_req.clone()],
            &mut FlyingPolicy::default(),
            Strategy::SoftPreempt,
        )
        .unwrap();
    c.shutdown();

    assert_eq!(soft.outputs[&1].len(), 6);
    assert_eq!(soft.outputs[&2].len(), 5);

    // The speculatively-started TP request must emit the same tokens as a
    // clean static run (recompute preserved its state).
    let mut c = Cluster::start(&m, "llama-tiny", 2).unwrap();
    let solo = c
        .run_trace(vec![req(2, 20, 5)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(soft.outputs[&2], solo.outputs[&2]);
}

#[test]
fn moe_model_serves_end_to_end() {
    let Some(m) = manifest() else { return };
    if m.models.get("moe-tiny").is_none() {
        return;
    }
    let mut c = Cluster::start(&m, "moe-tiny", 2).unwrap();
    let out = c
        .run_trace(vec![req(5, 22, 4)], &mut StaticTpPolicy { p: 2 }, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs[&5].len(), 4);
}
