//! End-to-end coordinator tests over the deterministic stub backend: the
//! full serving path — chunked prefill, paged decode, group formation,
//! every switching strategy — with no PJRT dependency, so they run in
//! plain CI (`cargo test`).  Mirrors `tests/integration.rs` (which needs
//! `--features pjrt` + artifacts) including its key invariant: greedy
//! decoding emits the *identical* token sequence under DP, TP, and across
//! live DP<->TP switches.

use flying_serving::baselines::{StaticDpPolicy, StaticTpPolicy};
use flying_serving::control::{
    AdaptivePolicy, ControlConfig, ControlRuntime, ThresholdController,
};
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::{OverlapConfig, Strategy, SwitchConfig};
use flying_serving::coordinator::{Cluster, ClusterOutcome, ServeRequest};
use flying_serving::metrics::Recorder;
use flying_serving::model::{ModelCfg, StaticShapes};
use flying_serving::workload::{synth_prompt_tokens, synth_prompt_tokens_family, Priority};

fn cfg() -> ModelCfg {
    ModelCfg {
        name: "stub-tiny".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 8,
        ffn_hidden: 48,
        n_experts: 0,
        top_k: 0,
        n_blocks: 16,
        block_base: 4,
        max_ctx: 256,
        vocab: 258,
        pool_elems: 16 * 4 * 4 * 8,
    }
}

fn shapes() -> StaticShapes {
    StaticShapes { b_dec: 4, c_prefill: 16 }
}

fn cluster(n_engines: usize) -> Cluster {
    Cluster::start_stub(cfg(), shapes(), n_engines).unwrap()
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        id,
        prompt: synth_prompt_tokens(id, prompt_len),
        max_new,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    }
}

#[test]
fn dp_and_tp_emit_identical_tokens() {
    let trace = vec![req(1, 19, 6), req(2, 40, 5)];

    let mut c1 = cluster(2);
    let out_dp = c1
        .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c1.shutdown();

    let mut c2 = cluster(2);
    let out_tp = c2
        .run_trace(trace, &mut StaticTpPolicy { p: 2 }, Strategy::Sequential)
        .unwrap();
    c2.shutdown();

    assert_eq!(out_dp.outputs.len(), 2);
    assert_eq!(out_dp.outputs[&1].len(), 6);
    assert_eq!(out_dp.outputs[&2].len(), 5);
    assert_eq!(out_dp.outputs, out_tp.outputs, "DP vs TP token mismatch");
    assert!(out_dp.rejected.is_empty() && out_tp.rejected.is_empty());
    assert!(out_dp.n_steps > 0);
}

#[test]
fn deterministic_across_runs() {
    let trace = vec![req(7, 25, 4)];
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut c = cluster(1);
        let o = c
            .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
            .unwrap();
        c.shutdown();
        outs.push(o.outputs);
    }
    assert_eq!(outs[0], outs[1]);
}

#[test]
fn flying_policy_switches_and_preserves_outputs() {
    let mut trace = vec![];
    for i in 0..5u64 {
        let mut r = req(10 + i, 15 + 3 * i as usize, 4);
        r.arrival = 0.05 * i as f64;
        trace.push(r);
    }

    let mut c = cluster(2);
    let truth = c
        .run_trace(trace.clone(), &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();

    let mut c = cluster(2);
    let flying = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::HardPreempt)
        .unwrap();
    c.shutdown();

    assert_eq!(truth.outputs, flying.outputs);
    // The dynamic run must actually have exercised switching.
    assert!(
        !flying.switches.is_empty(),
        "flying policy never formed a TP group"
    );
    // Live switches are fast: the stub data plane makes the SetMode RPC +
    // communicator fetch essentially free.
    for s in &flying.switches {
        assert!(s.latency_s < 0.05, "switch took {}s", s.latency_s);
    }
}

#[test]
fn long_context_served_by_flying_rejected_by_static_dp() {
    let dp_cap = cfg().dp_token_capacity();

    // A request that cannot fit a single engine's KV pool.
    let long = ServeRequest {
        id: 99,
        prompt: synth_prompt_tokens(99, dp_cap + 10),
        max_new: 3,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    };

    let mut c = cluster(2);
    let dp = c
        .run_trace(vec![long.clone()], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(dp.rejected, vec![99], "static DP must OOM-reject");

    let mut c = cluster(2);
    let fly = c
        .run_trace(vec![long], &mut FlyingPolicy::default(), Strategy::HardPreempt)
        .unwrap();
    c.shutdown();
    assert!(fly.rejected.is_empty(), "flying must serve via TP merge");
    assert_eq!(fly.outputs[&99].len(), 3);
}

#[test]
fn hard_preempt_priority_interrupts_and_resumes() {
    // A normal request arrives first and starts decoding on DP; then a
    // high-priority request arrives and hard-preempts into a TP group.
    let mut background = req(1, 30, 8);
    background.arrival = 0.0;
    let mut priority = req(2, 12, 3);
    priority.priority = Priority::High;
    priority.arrival = 0.15;

    let mut c = cluster(2);
    let out = c
        .run_trace(
            vec![background.clone(), priority.clone()],
            &mut FlyingPolicy::default(),
            Strategy::HardPreempt,
        )
        .unwrap();
    c.shutdown();

    // Both complete with full outputs (background resumed after preemption).
    assert_eq!(out.outputs[&1].len(), 8);
    assert_eq!(out.outputs[&2].len(), 3);

    // Background tokens match an undisturbed run (KV survived the pause).
    let mut c = cluster(2);
    let solo = c
        .run_trace(vec![background], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs[&1], solo.outputs[&1]);
}

#[test]
fn soft_preempt_speculative_tokens_consistent() {
    let mut background = req(1, 30, 6);
    background.arrival = 0.0;
    let mut tp_req = req(2, 20, 5);
    tp_req.tp_demand = Some(2); // explicit TP demand triggers the bind path
    tp_req.arrival = 0.1;

    let mut c = cluster(2);
    let soft = c
        .run_trace(
            vec![background.clone(), tp_req.clone()],
            &mut FlyingPolicy::default(),
            Strategy::SoftPreempt,
        )
        .unwrap();
    c.shutdown();

    assert_eq!(soft.outputs[&1].len(), 6);
    assert_eq!(soft.outputs[&2].len(), 5);

    // The speculatively-started TP request must emit the same tokens as a
    // clean static run (recompute preserved its state).
    let mut c = cluster(2);
    let solo = c
        .run_trace(vec![req(2, 20, 5)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(soft.outputs[&2], solo.outputs[&2]);
}

#[test]
fn sequential_strategy_drains_then_binds() {
    let mut background = req(1, 20, 6);
    background.arrival = 0.0;
    let mut tp_req = req(2, 16, 4);
    tp_req.tp_demand = Some(2);
    tp_req.arrival = 0.1;

    let mut c = cluster(2);
    let out = c
        .run_trace(
            vec![background, tp_req],
            &mut FlyingPolicy::default(),
            Strategy::Sequential,
        )
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs[&1].len(), 6);
    assert_eq!(out.outputs[&2].len(), 4);
}

#[test]
fn adaptive_policy_serves_real_path_deterministically() {
    // The control plane's real-path adaptor: the same ControlRuntime the
    // simulator threads through its event core, driven here by the actual
    // coordinator over stub engines.  The real path's clock is wall time,
    // so *mode decisions* may differ between runs (a control tick can land
    // before or after an arrival) — but the asserted outcomes cannot:
    // greedy stub decoding emits identical tokens under DP, TP, and across
    // switches (the suite's core invariant), and rejection is decided by
    // the plan-independent constraint tiers, never by the fleet plan.
    let mk_trace = || {
        (0..20u64)
            .map(|i| {
                let mut r = req(i, 8 + (i as usize % 11), 3 + (i as usize % 3));
                r.priority = if i % 9 == 0 { Priority::High } else { Priority::Normal };
                r.arrival = 0.02 * i as f64;
                r
            })
            .collect::<Vec<_>>()
    };
    let run = || {
        let mut policy = AdaptivePolicy::new(ControlRuntime::new(
            Box::new(ThresholdController::default()),
            ControlConfig::default(),
        ));
        let mut c = cluster(2);
        let out = c
            .run_trace(mk_trace(), &mut policy, Strategy::HardPreempt)
            .unwrap();
        c.shutdown();
        (out.outputs, out.rejected)
    };
    let (outputs_a, rejected_a) = run();
    assert_eq!(outputs_a.len() + rejected_a.len(), 20);
    for (id, toks) in &outputs_a {
        assert!(!toks.is_empty(), "request {id} produced no tokens");
    }
    let (outputs_b, rejected_b) = run();
    assert_eq!(outputs_a, outputs_b);
    assert_eq!(rejected_a, rejected_b);
}

/// Drive the drain scenario by hand: a long DP resident opens a drain via
/// an explicit TP demand, then a short elastic request arrives.  With
/// backfill on the short request must bind onto a draining engine within a
/// couple of iterations (its predicted steps fit the drain horizon); with
/// backfill off the drain mask blocks it until the resident finishes.
fn drive_drain_scenario(backfill: bool) -> (Option<f64>, Recorder) {
    let mut c = cluster(2);
    c.set_switch_config(SwitchConfig { backfill, ..SwitchConfig::default() });
    let mut recorder = Recorder::new();
    let mut policy = FlyingPolicy::default();

    // Long-running DP resident: 1 prefill chunk + 27 decode steps.
    c.submit(req(1, 12, 28), &mut recorder);
    for _ in 0..3 {
        c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    }
    // Explicit TP demand opens a sequential drain over both engines.
    let mut tp = req(2, 16, 4);
    tp.tp_demand = Some(2);
    c.submit(tp, &mut recorder);
    c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    // Short elastic request: 1 prefill chunk + 1 decode step — far inside
    // the ~25-step drain horizon the resident still owes.
    c.submit(req(3, 8, 2), &mut recorder);
    for _ in 0..2 {
        c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    }
    let first_sched_short = recorder.get(3).and_then(|r| r.first_sched);

    // Run everything to completion (settle promotes the TP bind once the
    // residents — including any backfill — drain).
    for _ in 0..10_000 {
        if !c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap() {
            break;
        }
    }
    c.shutdown();
    (first_sched_short, recorder)
}

#[test]
fn backfill_admits_bounded_work_on_draining_engines() {
    let (sched_on, rec_on) = drive_drain_scenario(true);
    assert!(
        sched_on.is_some(),
        "backfill on: short request must bind onto the draining engine"
    );
    let (sched_off, rec_off) = drive_drain_scenario(false);
    assert!(
        sched_off.is_none(),
        "backfill off: the drain mask must block elastic admission"
    );
    // Both modes finish every request with full token counts.
    for rec in [&rec_on, &rec_off] {
        for (id, want) in [(1u64, 28usize), (2, 4), (3, 2)] {
            let r = rec.get(id).unwrap_or_else(|| panic!("request {id} lost"));
            assert!(r.finished.is_some(), "request {id} never finished");
            assert_eq!(r.token_times.len(), want, "request {id} token count");
        }
    }
}

#[test]
fn backfill_on_emits_identical_tokens_to_backfill_off() {
    // Backfill re-times work but must never change greedy token values:
    // the same trace under both switch configs produces identical outputs.
    let mk_trace = || {
        let mut trace = vec![req(1, 12, 20)];
        let mut tp = req(2, 16, 4);
        tp.tp_demand = Some(2);
        tp.arrival = 0.05;
        trace.push(tp);
        let mut short = req(3, 8, 3);
        short.arrival = 0.08;
        trace.push(short);
        trace
    };
    let run = |backfill: bool| {
        let mut c = cluster(2);
        c.set_switch_config(SwitchConfig { backfill, ..SwitchConfig::default() });
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::Sequential)
            .unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs, on.outputs);
    assert!(off.rejected.is_empty() && on.rejected.is_empty());
    // Both exercised the switch path (incremental settle still logs the
    // final promotion hop).
    assert!(!off.switches.is_empty() && !on.switches.is_empty());
}

/// A burst of four DP residents (the burst keeps `FlyingPolicy` from
/// opportunistically widening them to TP) plus an explicit-TP request that
/// soft-preempts: it runs speculatively on a member while the residents
/// drain, so the promotion always happens mid-decode with cached KV
/// (pos > 0) — the recompute path with `migrate` off, layout-preserving KV
/// migration (home-side re-tag + peer scatter) with it on.
fn spec_promotion_trace() -> Vec<ServeRequest> {
    // 1 prefill chunk + 3 decode steps each; 3 committed blocks per
    // resident leaves DP-layout headroom for the speculative bind.
    let mut trace: Vec<ServeRequest> = (1..=4).map(|i| req(i, 8, 4)).collect();
    let mut tp = req(5, 12, 20);
    tp.tp_demand = Some(2);
    trace.push(tp);
    trace
}

fn run_spec_promotion(migrate: bool) -> ClusterOutcome {
    let mut c = cluster(2);
    c.set_switch_config(SwitchConfig { migrate, ..SwitchConfig::default() });
    let out = c
        .run_trace(
            spec_promotion_trace(),
            &mut FlyingPolicy::default(),
            Strategy::SoftPreempt,
        )
        .unwrap();
    c.shutdown();
    out
}

#[test]
fn migrated_promotion_emits_identical_tokens_to_recompute() {
    let off = run_spec_promotion(false);
    let on = run_spec_promotion(true);
    assert_eq!(off.outputs.len(), 5);
    for i in 1..=4u64 {
        assert_eq!(off.outputs[&i].len(), 4);
    }
    assert_eq!(off.outputs[&5].len(), 20);
    // Migration re-times the promotion but must never change greedy tokens.
    assert_eq!(off.outputs, on.outputs, "migration changed token values");
    assert_eq!(off.recompute_tokens_avoided, 0, "flag off must recompute");
    assert!(
        on.recompute_tokens_avoided > 0,
        "promotion must carry the speculative KV instead of re-prefilling"
    );
    assert!(!on.switches.is_empty(), "promotion never formed the TP group");
    // The carried request's tokens also match an undisturbed static run —
    // the suite's core invariant, now across a migrated layout change.
    let mut c = cluster(2);
    let solo = c
        .run_trace(vec![req(5, 12, 20)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(on.outputs[&5], solo.outputs[&5]);
}

#[test]
fn migration_composes_with_backfill_switch_config() {
    // Both switch optimizations on at once: the drain admits bounded
    // elastic work AND the promotion migrates — outputs still match the
    // all-off baseline.
    let run = |cfg: SwitchConfig| {
        let mut c = cluster(2);
        c.set_switch_config(cfg);
        let mut trace = spec_promotion_trace();
        // Short elastic request behind the drain: blocked until the group
        // resolves with the optimizations off, backfilled onto a draining
        // member with them on — token values must not care either way.
        trace.push(req(6, 8, 2));
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), Strategy::SoftPreempt)
            .unwrap();
        c.shutdown();
        out
    };
    let base = run(SwitchConfig::default());
    let both = run(SwitchConfig {
        backfill: true,
        migrate: true,
        ..SwitchConfig::default()
    });
    assert_eq!(base.outputs, both.outputs);
    assert!(base.rejected.is_empty() && both.rejected.is_empty());
}

#[test]
fn calibrate_fits_a_sane_model_and_leaves_no_residue() {
    let mut c = cluster(2);
    let cm = c.calibrate().unwrap();
    // The fitted model is positive and self-consistent: measured-scale
    // costs, capacity pinned to the real block pool, and the installed
    // cluster model is the returned one.
    assert!(cm.prefill_s(16, 1) > 0.0);
    assert!(cm.decode_step_s(1, 64, 1) > 0.0);
    assert!(cm.hw.flops_bf16 > 0.0 && cm.hw.hbm_bw > 0.0);
    // Capacity is pinned to the real block pool (±1 token of f64 rounding).
    let cap = cm.kv_capacity_tokens(1) as i64;
    let want = cfg().dp_token_capacity() as i64;
    assert!((cap - want).abs() <= 1, "fitted capacity {cap} vs pool {want}");
    assert_eq!(
        c.migration_cost_model().model.name,
        "testbed-calibrated",
        "calibrate must install the fitted model as the scheduling model"
    );
    // Wider layouts are never slower per step under the fitted model (the
    // monotonicity the backfill predicate and migrate gate rely on).
    assert!(cm.prefill_s(64, 2) <= cm.prefill_s(64, 1));
    // The probe leaves no residue: a real trace on the same cluster
    // reports exactly its own requests.
    let out = c
        .run_trace(vec![req(1, 19, 6)], &mut StaticDpPolicy, Strategy::Sequential)
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs.len(), 1);
    assert_eq!(out.outputs[&1].len(), 6);
    assert!(out.rejected.is_empty());
}

#[test]
fn calibrated_costmodel_controller_serves_real_path() {
    // ROADMAP open item (resolved): `CostModelController` on the real path,
    // scoring layouts against the testbed-calibrated fit — the `--policy
    // adaptive` + calibrate wiring, driven here end to end over stub
    // engines.  Wall-clock control ticks may land differently between runs,
    // but greedy token values are invariant under any mode schedule (the
    // suite's core invariant), so outputs must match across runs.
    use flying_serving::control::CostModelController;
    let mk_trace = || {
        (0..18u64)
            .map(|i| {
                let mut r = req(i, 8 + (i as usize % 9), 3 + (i as usize % 3));
                r.tp_demand = if i % 13 == 0 { Some(2) } else { None };
                r.arrival = 0.02 * i as f64;
                r
            })
            .collect::<Vec<_>>()
    };
    let run = || {
        let mut c = cluster(2);
        let cm = c.calibrate().unwrap();
        let mut policy = AdaptivePolicy::new(ControlRuntime::new(
            Box::new(CostModelController::new(cm)),
            ControlConfig::default(),
        ));
        let out = c.run_trace(mk_trace(), &mut policy, Strategy::HardPreempt).unwrap();
        c.shutdown();
        (out.outputs, out.rejected)
    };
    let (outputs_a, rejected_a) = run();
    assert_eq!(outputs_a.len() + rejected_a.len(), 18);
    for (id, toks) in &outputs_a {
        assert!(!toks.is_empty(), "request {id} produced no tokens");
    }
    let (outputs_b, rejected_b) = run();
    assert_eq!(outputs_a, outputs_b);
    assert_eq!(rejected_a, rejected_b);
}

#[test]
fn wall_clock_backfill_predicate_admits_under_calibrated_model() {
    // Satellite check for the wall-clock predicate specifically under the
    // *calibrated* model (the drive_drain_scenario test covers the default
    // paper-scale model): prediction and horizon are denominated in the
    // same measured seconds, so the short request still backfills.
    let mut c = cluster(2);
    c.calibrate().unwrap();
    c.set_switch_config(SwitchConfig { backfill: true, ..SwitchConfig::default() });
    let mut recorder = Recorder::new();
    let mut policy = FlyingPolicy::default();
    c.submit(req(1, 12, 28), &mut recorder);
    for _ in 0..3 {
        c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    }
    let mut tp = req(2, 16, 4);
    tp.tp_demand = Some(2);
    c.submit(tp, &mut recorder);
    c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    c.submit(req(3, 8, 2), &mut recorder);
    for _ in 0..2 {
        c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap();
    }
    assert!(
        recorder.get(3).and_then(|r| r.first_sched).is_some(),
        "short request must backfill onto the draining engine under the calibrated model"
    );
    for _ in 0..10_000 {
        if !c.step_once(&mut policy, Strategy::Sequential, &mut recorder).unwrap() {
            break;
        }
    }
    c.shutdown();
    for (id, want) in [(1u64, 28usize), (2, 4), (3, 2)] {
        let r = recorder.get(id).unwrap_or_else(|| panic!("request {id} lost"));
        assert!(r.finished.is_some(), "request {id} never finished");
        assert_eq!(r.token_times.len(), want, "request {id} token count");
    }
}

// ---------------------------------------------------------------------------
// Step-pipeline overlap (ISSUE 9): `--overlap` re-times work inside the
// lockstep protocol — double-buffered decode arenas, co-issued
// prefill+decode envelopes, async migration collectives — but must never
// change a single emitted token or admission outcome.
// ---------------------------------------------------------------------------

#[test]
fn overlap_on_emits_identical_tokens_to_overlap_off() {
    // The mixed four-engine load exercises every overlap ingredient on the
    // real path: co-issued prefill+decode envelopes (arrivals land while
    // decode batches are busy), double-buffered prebuilds (long decode
    // stretches), and slot invalidation (TP promotions churn the layout).
    let mk_trace = || {
        (0..24u64)
            .map(|i| {
                let mut r = req(i, 8 + (i as usize % 13), 3 + (i as usize % 4));
                r.priority = if i % 7 == 0 { Priority::High } else { Priority::Normal };
                r.tp_demand = if i % 11 == 0 { Some(2) } else { None };
                r.arrival = 0.01 * i as f64;
                r
            })
            .collect::<Vec<_>>()
    };
    let run = |overlap: bool| {
        let mut c = cluster(4);
        if overlap {
            c.set_overlap_config(OverlapConfig { enabled: true, ..OverlapConfig::default() });
        }
        let out = c
            .run_trace(mk_trace(), &mut FlyingPolicy::default(), Strategy::HardPreempt)
            .unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs, on.outputs, "overlap changed token values");
    assert_eq!(off.rejected, on.rejected, "overlap changed admission outcomes");
    assert_eq!(off.outputs.len() + off.rejected.len(), 24);
    assert!(!on.switches.is_empty(), "trace never exercised switching");
}

#[test]
fn overlap_composes_with_migrate_and_backfill() {
    // All three switch-path optimizations at once: the drain backfills, the
    // promotion migrates, and the migration collective scatters
    // asynchronously inside the drain window.  The async completion must
    // still carry the speculative KV (recompute_tokens_avoided > 0) and
    // token values must match the overlap-off run exactly.
    let run = |overlap: bool| {
        let mut c = cluster(2);
        c.set_switch_config(SwitchConfig {
            backfill: true,
            migrate: true,
            ..SwitchConfig::default()
        });
        if overlap {
            c.set_overlap_config(OverlapConfig { enabled: true, ..OverlapConfig::default() });
        }
        let mut trace = spec_promotion_trace();
        trace.push(req(6, 8, 2));
        let out = c
            .run_trace(trace, &mut FlyingPolicy::default(), Strategy::SoftPreempt)
            .unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs, on.outputs, "async migration changed token values");
    assert!(off.rejected.is_empty() && on.rejected.is_empty());
    assert!(!on.switches.is_empty(), "promotion never formed the TP group");
    assert!(
        on.recompute_tokens_avoided > 0,
        "async transfer must still carry the speculative KV"
    );
    assert_eq!(
        off.recompute_tokens_avoided, on.recompute_tokens_avoided,
        "overlap re-times the transfer, never changes what it carries"
    );
}

// ---------------------------------------------------------------------------
// Cross-request prefix cache (ISSUE 10): `--prefix-cache` lets admission
// adopt KV blocks donated by finished requests that shared a prompt
// prefix — skipping their prefill entirely — and the adopted blocks ride
// the PR-4 migration path across DP↔TP switches.  Greedy token values must
// never change: the stub engine is position-keyed, so a request whose
// prefix was adopted rather than prefilled emits byte-identical output.
// ---------------------------------------------------------------------------

/// A request whose first `plen` prompt tokens come from family `fid`'s
/// shared stream (identical across ids) and whose tail diverges per id.
fn family_req(id: u64, prompt_len: usize, fid: u64, plen: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        id,
        prompt: synth_prompt_tokens_family(id, prompt_len, Some((fid, plen))),
        max_new,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    }
}

#[test]
fn prefix_cache_on_emits_identical_tokens_to_off() {
    // One donor whose whole 16-token prompt is the family prefix, then
    // three followers sharing it with divergent 8-token tails.  The
    // followers arrive well after the donor finishes (sub-millisecond stub
    // steps vs. 0.25 s gaps), so with the cache on each follower adopts
    // the donated prefix at admission instead of prefilling it.
    let mk_trace = || {
        let mut trace = vec![family_req(1, 16, 42, 16, 2)];
        for i in 0..3u64 {
            let mut r = family_req(2 + i, 24, 42, 16, 4);
            r.arrival = 0.25 + 0.05 * i as f64;
            trace.push(r);
        }
        trace
    };
    let run = |prefix: bool| {
        let mut c = cluster(1);
        if prefix {
            c.set_prefix_cache(true);
        }
        let out = c
            .run_trace(mk_trace(), &mut StaticDpPolicy, Strategy::Sequential)
            .unwrap();
        c.check_invariants().unwrap();
        c.shutdown();
        out
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.outputs, on.outputs, "prefix cache changed token values");
    assert!(off.rejected.is_empty() && on.rejected.is_empty());
    assert_eq!(off.prefill_tokens_avoided, 0, "flag off must prefill everything");
    assert!(
        on.prefill_tokens_avoided > 0,
        "no follower adopted the donated prefix"
    );
    for i in 2..=4u64 {
        assert_eq!(on.outputs[&i].len(), 4, "follower {i} token count");
    }
}

#[test]
fn shared_prefix_survives_dp_tp_switch_without_reprefill() {
    let run = |prefix: bool| {
        let mut c = cluster(2);
        c.set_switch_config(SwitchConfig { migrate: true, ..SwitchConfig::default() });
        if prefix {
            c.set_prefix_cache(true);
        }
        let mut rec = Recorder::new();
        let mut policy = FlyingPolicy::default();
        // Phase 1: a burst of four donors (the burst keeps `FlyingPolicy`
        // from widening them to TP) whose whole prompt is the family
        // prefix; they spread over both engines, finish, and donate —
        // both adaptors' trees now hold the prefix.
        for i in 1..=4u64 {
            c.submit(family_req(i, 8, 7, 8, 2), &mut rec);
        }
        for _ in 0..50 {
            if !c.step_once(&mut policy, Strategy::SoftPreempt, &mut rec).unwrap() {
                break;
            }
        }
        // Phase 2: fresh residents occupy both engines so the explicit TP
        // demand below cannot bind directly — it must run speculatively
        // (through the DP admission path, where adoption lives) first.
        for i in 5..=8u64 {
            c.submit(req(i, 8, 4), &mut rec);
        }
        c.step_once(&mut policy, Strategy::SoftPreempt, &mut rec).unwrap();
        // Phase 3: the family follower demands TP=2.  Its speculative DP
        // bind adopts the donated prefix (those tokens are never
        // prefilled), then the drain promotes it mid-decode and the PR-4
        // migration carries the adopted blocks across the layout change.
        let mut f = family_req(9, 12, 7, 8, 20);
        f.tp_demand = Some(2);
        c.submit(f, &mut rec);
        for _ in 0..10_000 {
            if !c.step_once(&mut policy, Strategy::SoftPreempt, &mut rec).unwrap() {
                break;
            }
        }
        let adopted = c.prefill_tokens_avoided();
        let carried = c.recompute_tokens_avoided();
        c.check_invariants().unwrap();
        // An empty follow-up trace returns immediately with the outputs
        // and switch log the manual phase accumulated.
        let out = c.run_trace(vec![], &mut policy, Strategy::SoftPreempt).unwrap();
        c.shutdown();
        (out, adopted, carried)
    };
    let (off, off_adopted, off_carried) = run(false);
    let (on, on_adopted, on_carried) = run(true);
    assert_eq!(
        off.outputs, on.outputs,
        "prefix cache changed token values across the switch"
    );
    assert_eq!(off.outputs.len(), 9);
    assert_eq!(off_adopted, 0, "flag off must never adopt");
    assert!(on_adopted > 0, "follower never adopted the donated prefix");
    assert!(
        off_carried > 0 && on_carried > 0,
        "promotion must migrate, not re-prefill (off {off_carried}, on {on_carried})"
    );
    assert!(!on.switches.is_empty(), "no TP group formed");
    assert_eq!(on.outputs[&9].len(), 20);
    // The adopted-then-migrated request still matches an undisturbed
    // static run — the suite's core invariant, now with a prompt whose
    // prefix came out of the cache and then crossed a DP→TP flip.
    let mut c = cluster(2);
    let solo = c
        .run_trace(
            vec![family_req(9, 12, 7, 8, 20)],
            &mut StaticDpPolicy,
            Strategy::Sequential,
        )
        .unwrap();
    c.shutdown();
    assert_eq!(on.outputs[&9], solo.outputs[&9]);
}

#[test]
fn four_engine_mixed_load_completes() {
    // Wider cluster: mixed priorities, TP demands, and enough requests to
    // exercise the indexed free/draining sets and batch recycling.
    let mut trace = Vec::new();
    for i in 0..24u64 {
        let mut r = req(i, 8 + (i as usize % 13), 3 + (i as usize % 4));
        r.priority = if i % 7 == 0 { Priority::High } else { Priority::Normal };
        r.tp_demand = if i % 11 == 0 { Some(2) } else { None };
        r.arrival = 0.01 * i as f64;
        trace.push(r);
    }
    let mut c = cluster(4);
    let out = c
        .run_trace(trace, &mut FlyingPolicy::default(), Strategy::HardPreempt)
        .unwrap();
    c.shutdown();
    assert_eq!(out.outputs.len() + out.rejected.len(), 24);
    for (id, toks) in &out.outputs {
        assert!(!toks.is_empty(), "request {id} produced no tokens");
    }
}
