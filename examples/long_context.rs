//! Use Case 3 demo: long-context scaling by merging DP engines.
//!
//! A request whose KV exceeds one engine's pool OOMs a static-DP
//! deployment; FLYING SERVING merges engines into a TP group whose pooled
//! KV (block capacity B(p) = p * B_base, same physical bytes) fits it —
//! then releases the engines back to DP.  Also demonstrates the Table-2
//! point: the live switch is orders of magnitude faster than the cold
//! restart a static system would need.
//!
//!   make artifacts && cargo run --release --example long_context

use std::sync::Arc;

use flying_serving::baselines::StaticDpPolicy;
use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::sim::{CostModel, HwSpec, PaperModel};
use flying_serving::workload::{synth_prompt_tokens, Priority};

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let lm = manifest.model("llama-tiny")?;
    let dp_cap = lm.cfg.dp_token_capacity();
    let long_len = dp_cap + 64;
    println!(
        "DP capacity per engine: {} tokens; long request: {} tokens",
        dp_cap, long_len
    );

    let long_req = ServeRequest {
        id: 1,
        prompt: synth_prompt_tokens(1, long_len),
        max_new: 4,
        priority: Priority::Normal,
        tp_demand: None,
        arrival: 0.0,
    };

    // Static DP: rejected (the OOM the paper motivates Use Case 3 with).
    let mut c = Cluster::start(&manifest, "llama-tiny", 2)?;
    let dp = c.run_trace(vec![long_req.clone()], &mut StaticDpPolicy, Strategy::Sequential)?;
    c.shutdown();
    println!("static-dp: rejected={:?} (OOM as expected)", dp.rejected);
    assert_eq!(dp.rejected, vec![1]);

    // FLYING: merge 2 engines -> block capacity doubles -> request fits.
    let mut c = Cluster::start(&manifest, "llama-tiny", 2)?;
    let fly = c.run_trace(
        vec![long_req],
        &mut FlyingPolicy::default(),
        Strategy::HardPreempt,
    )?;
    c.shutdown();
    assert!(fly.rejected.is_empty());
    let rec = fly.recorder.get(1).unwrap();
    println!(
        "flying: served {} prompt tokens via TP merge; {} output tokens; ttft={:.0}ms",
        long_len,
        fly.outputs[&1].len(),
        rec.ttft().unwrap() * 1e3
    );
    let live_ms: f64 = fly.switches.iter().map(|s| s.latency_s).fold(0.0, f64::max) * 1e3;
    println!("max live switch latency: {live_ms:.3} ms ({} switches)", fly.switches.len());

    // Table-2 context: what a static system would pay instead (H200 model).
    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    println!("\npaper-scale contrast (Llama-70B on 8xH200, cost model):");
    for g in [2usize, 4, 8] {
        println!(
            "  {g} GPUs: max context {:>9} tokens, cold restart {:6.1}s",
            cm.kv_capacity_tokens(g),
            cm.cold_start_s(g)
        );
    }
    println!(
        "  live switch: {:.0} ms (~{:.0}x faster than cold start)",
        cm.live_switch_s() * 1e3,
        cm.cold_start_s(2) / cm.live_switch_s()
    );
    println!("\nlong_context OK");
    Ok(())
}
