//! Use Case 2 demo: priority-aware service differentiation on the real
//! cluster.  High-priority requests hard-preempt into TP groups (tight
//! latency); best-effort traffic keeps DP throughput; preempted requests
//! resume from resident KV without recomputation.
//!
//!   make artifacts && cargo run --release --example priority_serving

use std::sync::Arc;

use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::util::bench::Table;
use flying_serving::workload::{synth_prompt_tokens, Priority};

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let mut cluster = Cluster::start(&manifest, "llama-tiny", 2)?;

    // Background best-effort traffic + periodic high-priority requests.
    let mut trace = Vec::new();
    for i in 0..10u64 {
        trace.push(ServeRequest {
            id: i,
            prompt: synth_prompt_tokens(i, 40 + (i as usize % 5) * 10),
            max_new: 10,
            priority: Priority::Normal,
            tp_demand: None,
            arrival: 0.08 * i as f64,
        });
    }
    for j in 0..3u64 {
        trace.push(ServeRequest {
            id: 100 + j,
            prompt: synth_prompt_tokens(100 + j, 16),
            max_new: 6,
            priority: Priority::High,
            tp_demand: None,
            arrival: 0.25 + 0.3 * j as f64,
        });
    }

    let mut policy = FlyingPolicy::default();
    let out = cluster.run_trace(trace, &mut policy, Strategy::HardPreempt)?;
    cluster.shutdown();

    let hi = out.recorder.summary(Some(Priority::High));
    let all = out.recorder.summary(None);
    let mut t = Table::new(
        "Mixed-priority serving (real path, hard preempt)",
        &["class", "n", "mean TTFT (ms)", "mean TPOT (ms)", "p90 queue (ms)"],
    );
    t.row(&[
        "priority".into(),
        format!("{}", hi.n),
        format!("{:.1}", hi.mean_ttft * 1e3),
        format!("{:.1}", hi.mean_tpot * 1e3),
        format!("{:.1}", hi.p90_queue * 1e3),
    ]);
    t.row(&[
        "all".into(),
        format!("{}", all.n),
        format!("{:.1}", all.mean_ttft * 1e3),
        format!("{:.1}", all.mean_tpot * 1e3),
        format!("{:.1}", all.p90_queue * 1e3),
    ]);
    t.print();
    t.write_csv("priority_serving_real")?;

    println!(
        "\n{} live switches; every preempted request finished ({} outputs, {} rejected)",
        out.switches.len(),
        out.outputs.len(),
        out.rejected.len()
    );
    assert_eq!(out.outputs.len(), 13);
    assert!(
        hi.mean_ttft <= all.mean_ttft,
        "priority class must see no worse TTFT"
    );
    println!("priority_serving OK");
    Ok(())
}
