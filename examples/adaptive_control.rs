//! Adaptive reconfiguration control plane, end to end on the simulator —
//! no PJRT needed, runs anywhere `cargo` does:
//!
//!   cargo run --example adaptive_control
//!
//! Drives the `poisson_burst` scenario (quiet 2.5 req/s baseline punctured
//! by 25–35 req/s bursts) through the discrete-event simulator twice: once
//! pinned to full-width TP (the low-latency static choice), once under the
//! cost-model controller, which rides wide TP through the quiet phases and
//! scales out when the burst detector fires.  The adaptive run should keep
//! the static-TP trough latency without its burst-time collapse.

use flying_serving::control::{
    ControlConfig, ControlRuntime, Controller, CostModelController, StaticController,
};
use flying_serving::sim::{simulate_adaptive, CostModel, HwSpec, PaperModel, SimConfig};
use flying_serving::workload::Scenario;

fn main() {
    let cm = CostModel::new(HwSpec::default(), PaperModel::llama70b());
    let n_units = cm.hw.n_gpus / cm.model.min_gpus;
    let trace = Scenario::PoissonBurst.generate(7, 2000);
    println!(
        "{} · {} requests over {:.0}s · {} serving units",
        Scenario::PoissonBurst,
        trace.len(),
        trace.last().map(|r| r.arrival).unwrap_or(0.0),
        n_units
    );

    let ctrl_cfg = ControlConfig {
        long_threshold: cm.kv_capacity_tokens(cm.model.min_gpus),
        ..ControlConfig::default()
    };

    let controllers: [Box<dyn Controller>; 2] = [
        Box::new(StaticController::tp(n_units)),
        Box::new(CostModelController::new(cm.clone())),
    ];
    for controller in controllers {
        let mut rt = ControlRuntime::new(controller, ctrl_cfg);
        let o = simulate_adaptive(&cm, &trace, &SimConfig::default(), &mut rt);
        let s = o.recorder.summary(None);
        println!(
            "{:14} finished={:4} rejected={:3} ttft: mean={:6.2}s p90={:6.2}s | {} plan changes over {} ticks",
            rt.controller_name(),
            s.finished,
            o.rejected.len(),
            s.mean_ttft,
            s.p90_ttft,
            rt.plan_changes(),
            rt.ticks(),
        );
    }
}
