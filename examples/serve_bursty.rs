//! End-to-end validation driver (DESIGN.md requirement): replay a bursty
//! §6.1.3-style trace on the REAL engine cluster under static DP, static
//! TP, and FLYING SERVING, and report the paper's serving metrics.  This
//! proves all three layers compose: Pallas kernels -> AOT HLO -> PJRT
//! engines -> communicator pool -> dynamic scheduler.
//!
//!   make artifacts && cargo run --release --example serve_bursty
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use flying_serving::baselines::{StaticDpPolicy, StaticTpPolicy};
use flying_serving::coordinator::policy::{FlyingPolicy, Policy};
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::util::bench::Table;
use flying_serving::workload::{generate, synth_prompt_tokens, WorkloadCfg};

fn trace(seed: u64, n: usize) -> Vec<ServeRequest> {
    // Paper-shaped arrivals compressed to testbed scale: short low phases,
    // bursts, scaled lengths.
    let mut wl = WorkloadCfg::paper_scaled(seed, n);
    wl.prompt_range = (12, 120);
    wl.output_range = (4, 16);
    wl.phase_secs = 4.0;
    wl.low_rate = (1.0, 2.0);
    wl.high_rate = (8.0, 16.0);
    generate(&wl)
        .into_iter()
        .map(|r| ServeRequest {
            id: r.id,
            prompt: synth_prompt_tokens(r.id, r.prompt_len),
            max_new: r.output_len,
            priority: r.priority,
            tp_demand: None,
            arrival: r.arrival,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let n_engines = 2;
    let n_requests = 48;
    let t = trace(13, n_requests);
    println!(
        "bursty E2E: {} requests over {:.1}s on {} real engines (llama-tiny)",
        n_requests,
        t.last().unwrap().arrival,
        n_engines
    );

    let mut table = Table::new(
        "Real-path bursty serving (llama-tiny, 2 engines)",
        &["system", "mean TTFT (ms)", "p90 TTFT (ms)", "p50 TPOT (ms)", "p90 queue (ms)", "peak tok/s", "switches"],
    );

    let systems: Vec<(&str, Box<dyn Policy>, Strategy)> = vec![
        ("static-dp", Box::new(StaticDpPolicy), Strategy::Sequential),
        ("static-tp2", Box::new(StaticTpPolicy { p: 2 }), Strategy::Sequential),
        ("flying(hard)", Box::new(FlyingPolicy::default()), Strategy::HardPreempt),
        ("flying(soft)", Box::new(FlyingPolicy::default()), Strategy::SoftPreempt),
    ];

    let mut reference: Option<std::collections::BTreeMap<u64, Vec<i32>>> = None;
    for (name, mut policy, strategy) in systems {
        let mut cluster = Cluster::start(&manifest, "llama-tiny", n_engines)?;
        let out = cluster.run_trace(t.clone(), policy.as_mut(), strategy)?;
        cluster.shutdown();
        let s = out.recorder.summary(None);
        table.row(&[
            name.to_string(),
            format!("{:.1}", s.mean_ttft * 1e3),
            format!("{:.1}", s.p90_ttft * 1e3),
            format!("{:.1}", s.p50_tpot * 1e3),
            format!("{:.1}", s.p90_queue * 1e3),
            format!("{:.0}", s.peak_throughput),
            format!("{}", out.switches.len()),
        ]);
        // Token-level equivalence across systems (greedy decoding).
        match &reference {
            None => reference = Some(out.outputs),
            Some(r) => assert_eq!(r, &out.outputs, "{name} diverged from reference tokens"),
        }
    }

    table.print();
    let csv = table.write_csv("serve_bursty_real")?;
    println!("\nwrote {csv}; outputs token-identical across all systems ✓");
    Ok(())
}
