//! Quickstart: boot a 2-engine cluster on the tiny Llama analog, serve a
//! few requests with the FLYING policy, and show a live DP->TP switch.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use flying_serving::coordinator::policy::FlyingPolicy;
use flying_serving::coordinator::strategy::Strategy;
use flying_serving::coordinator::{Cluster, ServeRequest};
use flying_serving::runtime::Manifest;
use flying_serving::server::{detokenize, tokenize};
use flying_serving::workload::Priority;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    println!("booting 2 engines on llama-tiny (weights load once per engine)...");
    let mut cluster = Cluster::start(&manifest, "llama-tiny", 2)?;

    let reqs = vec![
        ServeRequest {
            id: 1,
            prompt: tokenize("The paper shows that static parallelism need not be "),
            max_new: 12,
            priority: Priority::Normal,
            tp_demand: None,
            arrival: 0.0,
        },
        ServeRequest {
            id: 2,
            prompt: tokenize("Dynamic DP-TP switching requires "),
            max_new: 12,
            priority: Priority::High, // gets a TP binding (Use Case 2)
            tp_demand: None,
            arrival: 0.05,
        },
        ServeRequest {
            id: 3,
            prompt: tokenize("KV cache blocks never move because "),
            max_new: 12,
            priority: Priority::Normal,
            tp_demand: Some(2), // explicit latency-strict TP demand
            arrival: 0.10,
        },
    ];

    let mut policy = FlyingPolicy::default();
    let out = cluster.run_trace(reqs, &mut policy, Strategy::HardPreempt)?;

    for (rid, tokens) in &out.outputs {
        let rec = out.recorder.get(*rid).unwrap();
        println!(
            "req {rid}: {:3} tokens, ttft={:6.1}ms tpot={:5.1}ms  text={:?}",
            tokens.len(),
            rec.ttft().unwrap_or(f64::NAN) * 1e3,
            rec.tpot().unwrap_or(f64::NAN) * 1e3,
            detokenize(tokens)
        );
    }
    println!("\nmode switches (live, no engine restart):");
    for s in &out.switches {
        println!(
            "  t={:7.3}s  group@{}  {}TP -> {}TP  in {:.3} ms",
            s.t,
            s.group_start,
            s.p_from,
            s.p_to,
            s.latency_s * 1e3
        );
    }
    cluster.shutdown();
    println!("\nquickstart OK");
    Ok(())
}
